"""ServingScenario: request-level what-ifs through the optimization registry.

This is the routing layer that makes serving policies first-class citizens
of the unified what-if API: ``continuous_batching``, ``chunked_prefill``,
``tp``, ``kv_offload`` and ``static_slots`` are *registered optimizations*
like ``amp`` or ``ddp`` — they parse from CLI stack specs, compose with
``|`` / ``Stack``, sweep over parameter grids, and report headroom bounds —
but instead of rewriting an existing graph they *adjust the
serving policy* and the scenario regenerates the request graph from the
workload (a policy change rewires which task waits on which; it is not
expressible as a duration rewrite).

Stack semantics on a :class:`ServingScenario`: serving-policy members fold
into the policy left-to-right, every other member (``bandwidth``, graph
rewrites, headroom wrappers) applies as a normal
:class:`~repro.core.transform.GraphTransform` over the regenerated graph.
``tp:degree=8`` shards the cost model and routes the graph through
:meth:`repro.core.cluster.ClusterGraph.build`, which wires each per-step
all-reduce task into real ring legs across the 8 workers — the same
cluster machinery training what-ifs use.

Results are :class:`ServingPrediction`\\ s — a :class:`Prediction` plus
p50/p99 TTFT, per-output-token latency (TPOT), end-to-end latency,
goodput (generated tokens per simulated second) and per-lane utilization —
so ``.speedup``, ``.critical_path`` and the report/diff tooling work
unchanged.

Headroom bounds: the serving-policy optimizations target every *engine*
task (prefill/decode/collective/DMA/gates) but never the arrival process,
so erasing the targets leaves the open-loop arrival chain intact and the
idealized makespan is exactly the last arrival — a floor no policy can
beat, which makes ``opportunity_bound`` >= any realizable policy's speedup
(the acceptance criterion golden-tested in ``tests/test_serving.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.core.cluster import ClusterGraph, WorkerSpec
from repro.core.graph import DependencyGraph
from repro.core.optimize import (Optimization, OptimizationError, Prediction,
                                 Scenario, Stack, register)
from repro.core.simulate import SimResult, lane_utilization, simulate
from repro.core.task import Task
from repro.core.transform import GraphTransform
from .costs import ServingCostModel
from .graphgen import (ServingGraph, ServingPolicy, build_serving_graph,
                       slot_lane_classes)
from .workload import Workload

# attrs["serving"] values of engine work (everything but the arrival
# process) — the serving optimizations' headroom-erasure target set
_ENGINE_WORK = ("prefill", "decode", "coll", "dma", "gate")


def _engine_task(t: Task) -> bool:
    return t.attrs.get("serving") in _ENGINE_WORK


# ==================================================== serving optimizations
class ServingOptimization(Optimization):
    """Base for registered optimizations that adjust the serving policy.

    They cannot transform an arbitrary training graph (a batching policy
    is a graph *generator* choice), so :meth:`build` raises — the
    :class:`PipelineParallel` pattern — and :class:`ServingScenario`
    intercepts them via :meth:`adjust` before graph generation instead.
    """

    def build(self, s: Scenario, tf: GraphTransform) -> None:
        raise OptimizationError(
            f"{self.name!r} is a serving-policy optimization; evaluate it "
            f"via a repro.serving.ServingScenario (it regenerates the "
            f"request graph rather than rewriting an existing one)")

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        raise NotImplementedError

    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        """Erase all engine work, keep arrivals: the idealized makespan is
        the last arrival — the open-loop floor every policy obeys, so the
        bound always covers the realized speedup.  On non-serving graphs
        the predicate matches nothing (bound exactly 1.0x, ranked out)."""
        return _engine_task


@register("continuous_batching", "cb")
@dataclasses.dataclass(frozen=True)
class ContinuousBatching(ServingOptimization):
    """Admit/retire requests at every decode-step boundary instead of
    draining whole static batches.  ``slots=0`` keeps the scenario
    policy's slot count."""

    slots: int = 0

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        kw: Dict[str, Any] = {"mode": "continuous"}
        if self.slots:
            kw["slots"] = self.slots
        return dataclasses.replace(policy, **kw)


@register("static_slots")
@dataclasses.dataclass(frozen=True)
class StaticSlots(ServingOptimization):
    """Seed-engine semantics: admit a batch, drain it completely.
    ``slots=0`` keeps the scenario policy's slot count."""

    slots: int = 0

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        kw: Dict[str, Any] = {"mode": "static"}
        if self.slots:
            kw["slots"] = self.slots
        return dataclasses.replace(policy, **kw)

    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        return None     # restructures batching; no shrink-only bound


@register("chunked_prefill")
@dataclasses.dataclass(frozen=True)
class ChunkedPrefill(ServingOptimization):
    """Split prompts into ``chunk``-token pieces that ride along decode
    steps instead of stalling them (TTFT interference removal)."""

    chunk: int = 512

    def __post_init__(self) -> None:
        if self.chunk < 1:
            raise OptimizationError(
                f"chunked_prefill needs chunk >= 1 tokens, got {self.chunk}")

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        return dataclasses.replace(policy, prefill_chunk=self.chunk)


@register("tp", "tensor_parallel")
@dataclasses.dataclass(frozen=True)
class TensorParallelServing(ServingOptimization):
    """Shard the model over ``degree`` chips: per-chip FLOPs/weights/KV
    divide, and each decode step gains an all-reduce that the cluster
    simulator wires into a real ring across the workers."""

    degree: int = 8

    def __post_init__(self) -> None:
        if self.degree < 1:
            raise OptimizationError(
                f"tp needs degree >= 1, got {self.degree}")

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        return dataclasses.replace(policy, tp_degree=self.degree)


@register("kv_offload")
@dataclasses.dataclass(frozen=True)
class KVOffload(ServingOptimization):
    """Admit past the device KV capacity and stream the excess residency
    over PCIe every step (adds DMA work; trades latency for admission)."""

    def adjust(self, policy: ServingPolicy) -> ServingPolicy:
        return dataclasses.replace(policy, kv_offload=True)

    def headroom_targets(self, s: Scenario
                         ) -> Optional[Callable[[Task], bool]]:
        return None     # adds work / restructures admission; no bound


def _split_serving(opt: Optimization
                   ) -> Tuple[List[ServingOptimization],
                              Optional[Optimization]]:
    """Partition a (possibly stacked) optimization into the serving-policy
    members (folded into the policy, in order) and the residual
    graph-transforming stack (``None`` when empty).  Headroom wrappers and
    other non-stack composites stay whole in the residual."""
    members = opt.opts if isinstance(opt, Stack) else (opt,)
    serving = [o for o in members if isinstance(o, ServingOptimization)]
    rest = [o for o in members if not isinstance(o, ServingOptimization)]
    if not serving:
        return [], opt
    if not rest:
        return serving, None
    return serving, (rest[0] if len(rest) == 1 else Stack(*rest))


# ============================================================== prediction
@dataclasses.dataclass
class ServingPrediction(Prediction):
    """A :class:`Prediction` plus request-level latency/goodput metrics.

    Latency percentiles are nearest-rank over per-request samples; TTFT is
    first-token finish minus arrival, TPOT the mean inter-token time of a
    request's decode stream, latency the full arrival->last-token span.
    ``goodput`` is generated tokens per simulated second of makespan.
    """

    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    goodput: float = 0.0
    tokens_generated: int = 0
    requests_completed: int = 0
    lane_util: Dict[str, float] = dataclasses.field(default_factory=dict)
    # folded slot-lane view: "slot:<rep> x<count>" -> utilization, one
    # entry per symmetry class (see graphgen.slot_lane_classes)
    slot_classes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __repr__(self) -> str:
        return (f"ServingPrediction({self.optimization.spec()}: "
                f"ttft p50/p99 {self.ttft_p50*1e3:.2f}/"
                f"{self.ttft_p99*1e3:.2f}ms, "
                f"goodput {self.goodput:.1f} tok/s, "
                f"{self.speedup:.2f}x)")


def _pct(samples: List[float], q: float) -> float:
    """Deterministic nearest-rank percentile (no interpolation)."""
    if not samples:
        return 0.0
    s = sorted(samples)
    k = max(0, min(len(s) - 1, math.ceil(q * len(s)) - 1))
    return s[k]


def serving_metrics(graph: DependencyGraph, result: SimResult,
                    workload: Workload, *, prefix: str = ""
                    ) -> Dict[str, Any]:
    """Extract request-level metrics from a simulated serving graph.

    Scans DECODE tasks by their ``attrs`` (rid/tok), so it works on the
    single-graph route and — with ``prefix="w0/"`` — on the cluster
    route's namespaced global graph (every worker replays the same decode
    stream; worker 0 is representative).
    """
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    count: Dict[int, int] = {}
    total = 0
    for t in graph.tasks():
        if t.attrs.get("serving") != "decode":
            continue
        if prefix and not t.thread.startswith(prefix):
            continue
        rid = t.attrs["rid"]
        f = result.finish[t.uid]
        total += 1
        count[rid] = count.get(rid, 0) + 1
        if rid not in first or f < first[rid]:
            first[rid] = f
        if rid not in last or f > last[rid]:
            last[rid] = f
    ttft: List[float] = []
    tpot: List[float] = []
    latency: List[float] = []
    completed = 0
    for r in workload.requests:
        if r.rid not in first:
            continue
        ttft.append(first[r.rid] - r.arrival)
        latency.append(last[r.rid] - r.arrival)
        n = count[r.rid]
        if n > 1:
            tpot.append((last[r.rid] - first[r.rid]) / (n - 1))
        if n >= r.output_tokens:
            completed += 1
    util = lane_utilization(result)
    if prefix:
        util = {th[len(prefix):]: u for th, u in util.items()
                if th.startswith(prefix)}
    slot_classes = {
        f"slot:{members[0]}" + (f" x{len(members)}"
                                if len(members) > 1 else ""):
        util.get(f"slot:{members[0]}", 0.0)
        for members in slot_lane_classes(result, prefix=prefix)}
    return {
        "ttft_p50": _pct(ttft, 0.50), "ttft_p99": _pct(ttft, 0.99),
        "tpot_p50": _pct(tpot, 0.50), "tpot_p99": _pct(tpot, 0.99),
        "latency_p50": _pct(latency, 0.50),
        "latency_p99": _pct(latency, 0.99),
        "goodput": total / result.makespan if result.makespan > 0 else 0.0,
        "tokens_generated": total,
        "requests_completed": completed,
        "lane_util": util,
        "slot_classes": slot_classes,
    }


# ================================================================ scenario
@dataclasses.dataclass
class ServingScenario(Scenario):
    """A :class:`Scenario` whose baseline graph is *generated* from an
    open-loop workload under a serving policy.

    ``workload``/``serving_cost``/``policy`` replace the training
    scenario's profiled graph as ground truth; ``predict``/``evaluate``/
    ``sweep``/``diff_against``/``opportunity`` all work, returning
    :class:`ServingPrediction`\\ s.  ``workers`` stays 1 — multi-chip
    routing is decided by the (possibly what-if-adjusted) policy's
    ``tp_degree``, which builds the namespaced cluster graph with real
    collective rings.
    """

    workload: Optional[Workload] = None
    policy: ServingPolicy = dataclasses.field(default_factory=ServingPolicy)
    serving_cost: ServingCostModel = dataclasses.field(
        default_factory=ServingCostModel)

    def __post_init__(self) -> None:
        if self.workload is None:
            raise OptimizationError(
                "ServingScenario needs a repro.serving.Workload")
        self._sgraph = build_serving_graph(self.workload, self.serving_cost,
                                           self.policy)
        if self.graph is None:
            self.graph = self._sgraph.graph
        super().__post_init__()

    # ------------------------------------------------------------- routing
    def _evaluate(self, opt: Optimization, *,
                  baseline: Optional[float] = None,
                  point: Optional[Dict[str, Any]] = None,
                  reuse: bool = True
                  ) -> Tuple[ServingPrediction, GraphTransform,
                             Optional[ClusterGraph]]:
        base = self.baseline().makespan if baseline is None else baseline
        serving, residual = _split_serving(opt)
        policy = self.policy
        for so in serving:
            policy = so.adjust(policy)
        fresh = policy != self.policy
        sg = build_serving_graph(self.workload, self.serving_cost, policy) \
            if fresh else self._sgraph
        # a fresh graph is ours to mutate; the cached baseline graph must
        # be copied before a residual stack rewrites it
        tf = GraphTransform(sg.graph,
                            copy=(not fresh) and residual is not None)
        if residual is not None:
            residual.build(self, tf)
        pt = dict(point or {})
        if policy.tp_degree > 1:
            cg = ClusterGraph.build(
                tf.graph, [WorkerSpec() for _ in range(policy.tp_degree)],
                cost=self.cost, collective_mode=self.collective_mode,
                schedule=tf.schedule)
            cres = cg.simulate()
            metrics = serving_metrics(cg.graph, cres.global_result,
                                      self.workload, prefix="w0/")
            return (ServingPrediction(opt, base, cres.makespan,
                                      cres.global_result, cres, pt,
                                      graph=cg.graph, schedule=cg.schedule,
                                      byte_maps=self._byte_maps(),
                                      **metrics), tf, cg)
        res = simulate(tf.graph, tf.schedule)
        metrics = serving_metrics(tf.graph, res, self.workload)
        return (ServingPrediction(opt, base, res.makespan, res, None, pt,
                                  graph=tf.graph, schedule=tf.schedule,
                                  byte_maps=self._byte_maps(),
                                  **metrics), tf, None)

    def sweep(self, opt, grid, *, reuse: bool = True
              ) -> List[ServingPrediction]:
        """Grid sweep; serving points never share builds (a policy change
        regenerates the graph, and the base sweep's reuse fast paths
        construct plain :class:`Prediction`\\ s that would drop the
        latency metrics), so ``reuse`` is forced off."""
        return super().sweep(opt, grid, reuse=False)

    # ------------------------------------------------------------- helpers
    def serving_graph(self, opt: Union[str, Optimization, None] = None
                      ) -> ServingGraph:
        """The generated :class:`ServingGraph` for the baseline policy or
        for a (possibly stacked) what-if's folded policy — bookkeeping
        (tokens emitted, step counts) for tests and reports."""
        if opt is None:
            return self._sgraph
        from repro.core.optimize import _resolve
        serving, _ = _split_serving(_resolve(opt))
        policy = self.policy
        for so in serving:
            policy = so.adjust(policy)
        if policy == self.policy:
            return self._sgraph
        return build_serving_graph(self.workload, self.serving_cost, policy)


# ================================================================= report
def format_serving_table(preds: List[ServingPrediction]) -> str:
    """Fixed-width latency/goodput table for the serve_sim CLI."""
    hdr = (f"{'what-if':<44} {'ttft p50':>9} {'ttft p99':>9} "
           f"{'tpot p50':>9} {'lat p99':>9} {'goodput':>10} {'speedup':>8}")
    lines = [hdr, "-" * len(hdr)]
    for p in preds:
        spec = p.optimization.spec()
        if len(spec) > 43:
            spec = spec[:40] + "..."
        lines.append(
            f"{spec:<44} {p.ttft_p50*1e3:>7.2f}ms {p.ttft_p99*1e3:>7.2f}ms "
            f"{p.tpot_p50*1e3:>7.2f}ms {p.latency_p99*1e3:>7.2f}ms "
            f"{p.goodput:>6.1f}t/s {p.speedup:>7.2f}x")
    return "\n".join(lines)
