"""Request-level serving workloads: seeded open-loop arrival processes.

The serving simulator (ROADMAP item 3, "heavy traffic from millions of
users") is driven open-loop: requests arrive on their own clock regardless
of whether the engine keeps up — the regime in which batching policies
actually differ.  A :class:`Workload` is an immutable, *seed-deterministic*
list of :class:`RequestSpec`s; the same ``(rate, duration, seed, length
distributions)`` tuple produces a bit-identical request list on every run,
which is what makes :class:`repro.serving.ServingPrediction`s reproducible
down to the float (an acceptance criterion of the subsystem).

Two generators:

* :func:`poisson_workload` — Poisson arrivals (exponential inter-arrival
  gaps) with lognormal prompt/output token lengths, the standard
  open-loop load model;
* :func:`trace_workload` — replay a request log (list of dicts or a JSONL
  file with ``arrival`` / ``prompt_tokens`` / ``output_tokens`` records),
  for production traces.

Everything downstream (graph generation, metrics) treats the workload as
ground truth; :func:`scale_arrivals` compresses the arrival clock to
model a rate change on the *same* request population (the apples-to-apples
comparison the monotone-latency property tests use).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One request of an open-loop workload (times in seconds, lengths in
    tokens).  ``output_tokens`` is the request's full decode budget — the
    simulator generates exactly this many tokens (token conservation)."""

    rid: int
    arrival: float
    prompt_tokens: int
    output_tokens: int

    def __post_init__(self) -> None:
        if self.prompt_tokens < 1 or self.output_tokens < 1:
            raise ValueError(
                f"request {self.rid}: prompt/output token counts must be "
                f">= 1, got {self.prompt_tokens}/{self.output_tokens}")
        if self.arrival < 0:
            raise ValueError(f"request {self.rid}: negative arrival time")


@dataclasses.dataclass(frozen=True)
class Workload:
    """An immutable arrival-ordered request list plus its provenance."""

    requests: Tuple[RequestSpec, ...]
    duration: float                 # arrival-window length (seconds)
    seed: Optional[int] = None      # None for trace-driven workloads
    source: str = "poisson"         # "poisson" | "trace" | "explicit"

    def __post_init__(self) -> None:
        arr = [r.arrival for r in self.requests]
        if any(b < a for a, b in zip(arr, arr[1:])):
            object.__setattr__(
                self, "requests",
                tuple(sorted(self.requests, key=lambda r: (r.arrival, r.rid))))

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def total_output_tokens(self) -> int:
        return sum(r.output_tokens for r in self.requests)

    @property
    def total_prompt_tokens(self) -> int:
        return sum(r.prompt_tokens for r in self.requests)

    @property
    def last_arrival(self) -> float:
        return self.requests[-1].arrival if self.requests else 0.0

    def offered_rate(self) -> float:
        """Realized request arrival rate (requests/s) over the window."""
        if not self.requests or self.duration <= 0:
            return 0.0
        return len(self.requests) / self.duration


def poisson_workload(rate: float, duration: float, *, seed: int = 0,
                     prompt_mean: int = 512, prompt_sigma: float = 0.6,
                     output_mean: int = 128, output_sigma: float = 0.6,
                     max_prompt: int = 32768,
                     max_output: int = 8192) -> Workload:
    """Seeded Poisson arrivals over ``[0, duration)`` at ``rate`` req/s.

    Prompt/output lengths are lognormal (median ``*_mean`` tokens, log-std
    ``*_sigma``) clamped to ``[1, max_*]`` — the long right tail is the
    point: a few huge prompts are what chunked prefill exists for.  All
    randomness flows through one ``numpy.random.default_rng(seed)``, so the
    workload is bit-identical across runs and platforms for a given
    parameter tuple.
    """
    if rate <= 0 or duration <= 0:
        raise ValueError(f"rate and duration must be > 0, got "
                         f"rate={rate}, duration={duration}")
    rng = np.random.default_rng(seed)
    reqs: List[RequestSpec] = []
    t = 0.0
    rid = 0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration:
            break
        p = int(min(max(1, round(math.exp(
            math.log(prompt_mean) + prompt_sigma * float(rng.standard_normal())
        ))), max_prompt))
        o = int(min(max(1, round(math.exp(
            math.log(output_mean) + output_sigma * float(rng.standard_normal())
        ))), max_output))
        reqs.append(RequestSpec(rid=rid, arrival=t, prompt_tokens=p,
                                output_tokens=o))
        rid += 1
    return Workload(tuple(reqs), duration=duration, seed=seed,
                    source="poisson")


def trace_workload(records: Any, *, duration: Optional[float] = None
                   ) -> Workload:
    """Build a workload from a request log.

    ``records`` is an iterable of dicts (or a path to a JSONL file of such
    dicts) with keys ``arrival`` (seconds), ``prompt_tokens``,
    ``output_tokens`` and optional ``rid``.  Records are sorted by arrival;
    ``duration`` defaults to the last arrival.
    """
    if isinstance(records, str):
        with open(records) as f:
            records = [json.loads(line) for line in f if line.strip()]
    reqs = []
    for i, rec in enumerate(records):
        reqs.append(RequestSpec(
            rid=int(rec.get("rid", i)), arrival=float(rec["arrival"]),
            prompt_tokens=int(rec["prompt_tokens"]),
            output_tokens=int(rec["output_tokens"])))
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    dur = duration if duration is not None \
        else (reqs[-1].arrival if reqs else 0.0)
    return Workload(tuple(reqs), duration=dur, seed=None, source="trace")


def explicit_workload(specs: Sequence[Tuple[float, int, int]],
                      *, duration: Optional[float] = None) -> Workload:
    """Workload from explicit ``(arrival, prompt_tokens, output_tokens)``
    tuples — the test-suite's way to pin exact scenarios (e.g. a single
    full batch at t=0 for the static drain-time invariant)."""
    reqs = tuple(RequestSpec(rid=i, arrival=a, prompt_tokens=p,
                             output_tokens=o)
                 for i, (a, p, o) in enumerate(specs))
    dur = duration if duration is not None \
        else (max((r.arrival for r in reqs), default=0.0))
    return Workload(reqs, duration=dur, seed=None, source="explicit")


def scale_arrivals(workload: Workload, factor: float) -> Workload:
    """Compress (``factor < 1``) or stretch the arrival clock of the *same*
    request population — rate becomes ``rate / factor`` with identical
    prompts/outputs, the controlled comparison behind the monotone-latency
    property (higher rate on the same work must not reduce latency)."""
    if factor <= 0:
        raise ValueError(f"arrival scale factor must be > 0, got {factor}")
    reqs = tuple(dataclasses.replace(r, arrival=r.arrival * factor)
                 for r in workload.requests)
    return Workload(reqs, duration=workload.duration * factor,
                    seed=workload.seed, source=workload.source)
