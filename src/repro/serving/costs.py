"""Analytic serving cost model: prefill and decode-step durations.

The training-side :class:`repro.core.costmodel.CostModel` prices one task
from its FLOPs/bytes; serving needs two *shape-level* quantities instead —
the wall-clock of one prefill over ``p`` prompt tokens and of one decode
step over the current batch and KV residency.  Both are rooflines over the
same :class:`~repro.core.task.HardwareSpec` constants:

  prefill(p)        = max(p * flops_per_token / peak_flops,
                          (weight_bytes + p * kv_bytes_per_token) / hbm_bw)
                        * prefill_scale + step_overhead
  decode_step(b, k) = max(b * flops_per_token / peak_flops,
                          (weight_bytes + k * kv_bytes_per_token) / hbm_bw)
                        * decode_scale + step_overhead

where ``b`` is the active batch and ``k`` the resident KV tokens the step
reads — decode is memory-bound at small batch (weights dominate) and the
model is monotone in both arguments, which the latency properties rely on.

``prefill_scale`` / ``decode_scale`` / ``step_overhead`` are *fittable
constants* in exactly the :meth:`CostModel.fittable_constants` /
:meth:`CostModel.with_constants` sense: the timing harness
(:mod:`repro.serving.measure`) runs the seed ``repro.serve.ServeEngine``'s
jitted prefill/decode steps, fits the scales, and prints the
``ServingCostModel.with_constants({...})`` line to reuse; per-model fitted
defaults live in :mod:`repro.configs.serving`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.costmodel import FittableConstant
from repro.core.task import HardwareSpec, TPU_V5E

# Fraction of HBM the KV cache may occupy after weights (rest is
# activations/workspace) when deriving the default capacity.
_KV_HBM_FRACTION = 0.9


@dataclasses.dataclass(frozen=True)
class ServingCostModel:
    """Per-model serving constants (derived or fitted) plus the roofline.

    Build one analytically with :meth:`from_model_config` (pure shape
    math over a :class:`repro.models.model.ModelConfig`) and refine it
    with measured constants via :meth:`with_constants`.
    """

    hw: HardwareSpec = TPU_V5E
    flops_per_token: float = 2e9        # decode FLOPs per generated token
    prefill_flops_per_token: float = 2e9
    weight_bytes: float = 2e9           # resident parameter bytes
    kv_bytes_per_token: float = 1e5     # K+V bytes per resident token
    tp_coll_bytes_per_token: float = 1e4  # per-step TP all-reduce payload
    # ---- fittable constants (measure.py / with_constants) ---------------
    prefill_scale: float = 1.0
    decode_scale: float = 1.0
    step_overhead: float = 0.0          # fixed per-step host/dispatch cost

    # ------------------------------------------------------------ derive
    @classmethod
    def from_model_config(cls, cfg, hw: HardwareSpec = TPU_V5E
                          ) -> "ServingCostModel":
        """Analytic constants from a model config (no compilation):
        2*N_active FLOPs per token, bf16 weights, per-layer K+V heads."""
        from repro.models.model import active_params, count_params
        n_active = float(active_params(cfg))
        head_dim = cfg.head_dim or cfg.d_model // max(cfg.n_heads, 1)
        # K and V, bf16, per layer; SSM/hybrid archs keep a constant-size
        # state instead but the per-token bound still applies to their
        # attention blocks (window caps full-attention residency).
        kv = 2.0 * 2.0 * cfg.n_layers * max(cfg.n_kv_heads, 1) * head_dim
        return cls(hw=hw,
                   flops_per_token=2.0 * n_active,
                   prefill_flops_per_token=2.0 * n_active,
                   weight_bytes=2.0 * float(count_params(cfg)),
                   kv_bytes_per_token=kv,
                   tp_coll_bytes_per_token=2.0 * cfg.d_model * cfg.n_layers,
                   step_overhead=hw.host_dispatch)

    # ---------------------------------------------------------- rooflines
    def prefill_time(self, prompt_tokens: int) -> float:
        """Wall-clock of one prefill pass over ``prompt_tokens`` tokens."""
        flops = prompt_tokens * self.prefill_flops_per_token
        byts = self.weight_bytes + prompt_tokens * self.kv_bytes_per_token
        return max(flops / self.hw.peak_flops,
                   byts / self.hw.hbm_bandwidth) * self.prefill_scale \
            + self.step_overhead

    def decode_step_time(self, batch: int, kv_tokens: float) -> float:
        """Wall-clock of one decode step: ``batch`` active slots reading
        ``kv_tokens`` resident KV tokens.  Monotone non-decreasing in both
        arguments (the latency properties' load-monotonicity backbone)."""
        if batch <= 0:
            return 0.0
        flops = batch * self.flops_per_token
        byts = self.weight_bytes + kv_tokens * self.kv_bytes_per_token
        return max(flops / self.hw.peak_flops,
                   byts / self.hw.hbm_bandwidth) * self.decode_scale \
            + self.step_overhead

    def kv_offload_time(self, excess_tokens: float) -> float:
        """Per-step PCIe streaming cost of KV resident beyond HBM."""
        if excess_tokens <= 0:
            return 0.0
        return excess_tokens * self.kv_bytes_per_token \
            / self.hw.pcie_bandwidth

    def kv_capacity_tokens(self) -> float:
        """Device KV capacity: HBM minus weights, with headroom."""
        free = self.hw.hbm_bytes - self.weight_bytes
        if free <= 0 or self.kv_bytes_per_token <= 0:
            return 0.0
        return _KV_HBM_FRACTION * free / self.kv_bytes_per_token

    # ------------------------------------------------------ parallelism
    def parallel(self, degree: int) -> "ServingCostModel":
        """Tensor-parallel shard of this model over ``degree`` chips:
        weights, KV heads, and per-token FLOPs all divide; the fixed step
        overhead does not (each chip still dispatches every step)."""
        if degree <= 1:
            return self
        return dataclasses.replace(
            self,
            flops_per_token=self.flops_per_token / degree,
            prefill_flops_per_token=self.prefill_flops_per_token / degree,
            weight_bytes=self.weight_bytes / degree,
            kv_bytes_per_token=self.kv_bytes_per_token / degree)

    # ------------------------------------------------- fittable constants
    _FITTABLE = ("prefill_scale", "decode_scale", "step_overhead")

    def fittable_constants(self) -> List[FittableConstant]:
        """The measurable constants, in :class:`FittableConstant` form —
        the same contract :meth:`CostModel.fittable_constants` exposes to
        the calibration loop."""
        bounds = {"prefill_scale": (1e-3, 1e4, True),
                  "decode_scale": (1e-3, 1e4, True),
                  "step_overhead": (0.0, 1.0, False)}
        return [FittableConstant(n, getattr(self, n), lo, hi, log=log)
                for n in self._FITTABLE
                for (lo, hi, log) in (bounds[n],)]

    def with_constants(self, mapping: Dict[str, float]
                       ) -> "ServingCostModel":
        """Copy with measured constants applied (keys from
        :meth:`fittable_constants`) — the reuse line
        :mod:`repro.serving.measure` prints."""
        bad = [k for k in mapping if k not in self._FITTABLE]
        if bad:
            raise ValueError(
                f"unknown serving constant(s) {bad}; fittable: "
                f"{list(self._FITTABLE)}")
        return dataclasses.replace(
            self, **{k: float(v) for k, v in mapping.items()})
