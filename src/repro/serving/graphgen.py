"""Lower a serving policy over a workload into a dependency graph.

This is the serving analogue of the training-step graph builders: one
deterministic pass over the workload plays the batching policy forward
(using the same :class:`~repro.serving.costs.ServingCostModel` durations
the simulator will see) and emits a graph whose *edges encode exactly the
waits the policy imposes*, so :func:`repro.core.simulate.simulate`
reproduces the policy's timeline — and every existing tool (critical
paths, trace export/diff, headroom erasure, cluster wiring) works on it
unchanged.

Graph encoding (lanes are simulator threads):

* ``arrivals`` — one zero-duration task per request whose ``gap`` is the
  inter-arrival time, so request ``i``'s arrival task *completes* at
  exactly ``arrival_i``; everything a request does is gated on it.  This
  is what makes the makespan of *any* policy >= the last arrival — the
  floor the serving ``headroom_targets`` bounds lean on.
* ``device`` — PREFILL tasks (one per request; one per chunk when chunked
  prefill is on), program-order serialized like a real engine's compute
  stream.
* ``sched`` — zero-duration SYNC gate tasks: one admission gate per batch
  (static) and one gate per decode step.  A step's gate waits on the
  previous step's token tasks and on any prefill work the policy ordered
  before it; its children are the step's token tasks.  Scheduler policies
  differ *only* in how these gates are wired.
* ``slot:<k>`` — chained per-token DECODE tasks on batch-slot lanes; slot
  lanes are the per-lane utilization the prediction reports.
* ``coll`` — per-step tensor-parallel all-reduce tasks (``attrs
  ["collective"]``), wired into rings by
  :meth:`repro.core.cluster.ClusterGraph.wire_collective_group` when the
  scenario routes through the cluster simulator.
* ``dma`` — KV-offload streaming tasks (PCIe) when residency exceeds the
  device capacity and ``kv_offload`` is on.

KV-cache residency is a capacity constraint at admission: a request
reserves its full footprint (``prompt + output`` tokens) against
``kv_capacity_tokens`` and is queued until the reservation fits (or, with
``kv_offload``, admitted anyway with the excess streamed over PCIe each
step).

Static-batch drain-time invariant
---------------------------------
In ``mode="static"`` the engine admits up to ``slots`` arrived requests,
prefills them, then decodes the whole batch in lockstep for ``budget =
max(member output_tokens)`` steps — finished slots idle until the batch
drains, exactly the seed ``repro/serve.ServeEngine`` semantics.  Every
step reads the batch's full pre-allocated KV, so all steps cost the same
and the simulated makespan of a single full batch arriving at t=0 equals
``sum(prefill_i) + budget * decode_step`` to float precision — the
subsystem's calibration anchor, asserted by ``tests/test_serving.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.graph import DependencyGraph
from repro.core.task import Task, TaskKind, DEVICE_STREAM
from repro.obs.spans import span as _obs_span
from .costs import ServingCostModel
from .workload import RequestSpec, Workload

ARRIVAL_LANE = "arrivals"
SCHED_LANE = "sched"
COLL_LANE = "coll"
DMA_LANE = "dma"


def slot_lane(k: int) -> str:
    return f"slot:{k}"


def slot_lane_classes(result, *, prefix: str = "") -> List[Tuple[int, ...]]:
    """Partition ``slot:<k>`` lanes into symmetry classes.

    Batch-slot lanes are interchangeable by construction — the engine
    admits requests into whichever slot is free — so lanes whose
    simulated busy time is exactly equal form one equivalence class.
    Returns slot-index tuples (each ascending, ordered by busy time),
    mirroring the cluster layer's worker classes: at 10k scale, report
    one representative lane per class instead of every lane.  Pass
    ``prefix="w0/"`` to scope to one worker of a namespaced cluster
    graph.
    """
    want = prefix + "slot:"
    groups: Dict[float, List[int]] = {}
    for th, busy in result.thread_busy.items():
        if not th.startswith(want):
            continue
        try:
            k = int(th[len(want):])
        except ValueError:
            continue
        groups.setdefault(busy, []).append(k)
    return [tuple(sorted(members))
            for _, members in sorted(groups.items())]


@dataclasses.dataclass(frozen=True)
class ServingPolicy:
    """How the engine batches requests — the knob surface the registered
    serving optimizations adjust (see :mod:`repro.serving.scenario`).

    ``mode="static"`` is the baseline (seed-engine semantics, see module
    docstring); ``mode="continuous"`` admits/retires requests at every
    decode-step boundary.  ``prefill_chunk > 0`` splits prefills into
    chunks that ride along decode steps instead of stalling them
    (continuous mode only — static mode just splits the prefill tasks).
    ``kv_capacity_tokens == 0`` derives the capacity from the cost model;
    ``float("inf")`` disables the constraint.  ``tp_degree > 1`` shards
    the model over that many workers and inserts per-step all-reduce
    collectives for the cluster simulator to wire into rings.
    """

    mode: str = "static"
    slots: int = 8
    prefill_chunk: int = 0
    kv_capacity_tokens: float = 0.0
    kv_offload: bool = False
    tp_degree: int = 1

    def __post_init__(self) -> None:
        if self.mode not in ("static", "continuous"):
            raise ValueError(
                f"serving mode must be 'static' or 'continuous', "
                f"got {self.mode!r}")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.prefill_chunk < 0 or self.tp_degree < 1:
            raise ValueError(
                f"bad policy: prefill_chunk={self.prefill_chunk}, "
                f"tp_degree={self.tp_degree}")

    def capacity(self, cost: ServingCostModel) -> float:
        if self.kv_capacity_tokens > 0:
            return self.kv_capacity_tokens
        cap = cost.kv_capacity_tokens()
        return cap if cap > 0 else float("inf")


@dataclasses.dataclass
class ServingGraph:
    """The lowered graph plus the request bookkeeping metrics need."""

    graph: DependencyGraph
    workload: Workload
    policy: ServingPolicy
    cost: ServingCostModel          # already sharded by tp_degree
    # rid -> number of emitted DECODE token tasks (== output_tokens)
    tokens_emitted: Dict[int, int]
    num_steps: int                  # decode-step gates emitted
    num_batches: int                # admissions (static) / 1 (continuous)


class _Emitter:
    """Shared graph-emission state for both policy loops."""

    def __init__(self, wl: Workload, cost: ServingCostModel,
                 pol: ServingPolicy) -> None:
        self.g = DependencyGraph()
        self.cost = cost
        self.pol = pol
        self.arrival: Dict[int, Task] = {}
        self.tokens: Dict[int, int] = {}
        self.num_steps = 0
        prev = 0.0
        for r in wl.requests:
            t = self.g.add_task(Task(
                name=f"arrive:r{r.rid}", kind=TaskKind.HOST,
                thread=ARRIVAL_LANE, duration=0.0, gap=r.arrival - prev,
                phase="serve",
                attrs={"serving": "arrival", "rid": r.rid}))
            self.arrival[r.rid] = t
            prev = r.arrival

    def gate(self, name: str, parents: List[Task]) -> Task:
        t = self.g.add_task(Task(
            name=name, kind=TaskKind.SYNC, thread=SCHED_LANE, duration=0.0,
            phase="serve", attrs={"serving": "gate"}))
        for p in parents:
            self.g.add_edge(p, t)
        return t

    def prefill(self, r: RequestSpec, tokens: int, dur: float,
                parents: List[Task], *, chunk: int = -1) -> Task:
        name = f"prefill:r{r.rid}" if chunk < 0 \
            else f"prefill:r{r.rid}:c{chunk}"
        t = self.g.add_task(Task(
            name=name, kind=TaskKind.COMPUTE, thread=DEVICE_STREAM,
            duration=dur, phase="serve",
            flops=tokens * self.cost.prefill_flops_per_token,
            bytes_accessed=self.cost.weight_bytes
            + tokens * self.cost.kv_bytes_per_token,
            attrs={"serving": "prefill", "rid": r.rid, "tokens": tokens}))
        for p in parents:
            self.g.add_edge(p, t)
        return t

    def token(self, r: RequestSpec, slot: int, tok: int, dur: float,
              gate: Task) -> Task:
        self.tokens[r.rid] = self.tokens.get(r.rid, 0) + 1
        t = self.g.add_task(Task(
            name=f"decode:r{r.rid}:t{tok}", kind=TaskKind.COMPUTE,
            thread=slot_lane(slot), duration=dur, phase="serve",
            flops=self.cost.flops_per_token,
            attrs={"serving": "decode", "rid": r.rid, "tok": tok,
                   "slot": slot}))
        self.g.add_edge(gate, t)
        return t

    def collective(self, name: str, payload: float, dur: float,
                   parents: List[Task]) -> Task:
        t = self.g.add_task(Task(
            name=name, kind=TaskKind.COLLECTIVE, thread=COLL_LANE,
            duration=dur, phase="serve", comm_bytes=payload,
            attrs={"serving": "coll", "collective": "all-reduce"}))
        for p in parents:
            self.g.add_edge(p, t)
        return t

    def dma(self, name: str, excess_tokens: float, dur: float,
            parents: List[Task]) -> Task:
        t = self.g.add_task(Task(
            name=name, kind=TaskKind.OFFLOAD, thread=DMA_LANE,
            duration=dur, phase="serve",
            bytes_accessed=excess_tokens * self.cost.kv_bytes_per_token,
            attrs={"serving": "dma"}))
        for p in parents:
            self.g.add_edge(p, t)
        return t

    def step_coll_time(self, batch: int) -> float:
        """Estimated per-step TP all-reduce time (ring formula) — used by
        the policy loop's forward clock; the cluster wiring recomputes the
        real leg durations from ``comm_bytes`` when the graph is placed."""
        d = self.pol.tp_degree
        if d <= 1:
            return 0.0
        payload = batch * self.cost.tp_coll_bytes_per_token
        bw = self.cost.hw.ici_bandwidth
        return 2.0 * (d - 1) / d * payload / bw


def build_serving_graph(workload: Workload, cost: ServingCostModel,
                        policy: ServingPolicy) -> ServingGraph:
    """Lower ``policy`` over ``workload`` into a simulatable graph.

    The cost model is sharded by ``policy.tp_degree`` first, so task
    durations/FLOPs are per-chip; collectives carry the all-reduce payload
    for the cluster wiring.  O(requests + generated tokens) tasks.
    """
    with _obs_span("serving.graphgen", requests=len(workload.requests),
                   mode=policy.mode, tp=policy.tp_degree) as sp:
        sharded = cost.parallel(policy.tp_degree)
        em = _Emitter(workload, sharded, policy)
        if policy.mode == "static":
            batches = _static_loop(em, workload)
        else:
            batches = _continuous_loop(em, workload)
        em.g.validate()
        sp.note(tasks=len(em.g), tokens=em.tokens)
        return ServingGraph(graph=em.g, workload=workload, policy=policy,
                            cost=sharded, tokens_emitted=em.tokens,
                            num_steps=em.num_steps, num_batches=batches)


# ---------------------------------------------------------------- static
def _static_loop(em: _Emitter, wl: Workload) -> int:
    """Seed-engine semantics: admit a batch, prefill, decode in lockstep
    until the *whole batch* drains (budget = max member output)."""
    pol, cost = em.pol, em.cost
    cap = pol.capacity(cost)
    pending: List[RequestSpec] = list(wl.requests)
    prev_gate: Optional[Task] = None
    t_free = 0.0
    batches = 0
    while pending:
        # admission clock: engine free vs first pending arrival
        t_adm = max(t_free, pending[0].arrival)
        batch: List[RequestSpec] = []
        reserved = 0.0
        for r in pending:
            if len(batch) >= pol.slots or r.arrival > t_adm:
                break
            need = r.prompt_tokens + r.output_tokens
            if batch and not pol.kv_offload and reserved + need > cap:
                break               # KV capacity caps the batch
            batch.append(r)
            reserved += need
        pending = pending[len(batch):]
        batches += 1
        adm = em.gate(f"admit:b{batches - 1}",
                      ([prev_gate] if prev_gate else [])
                      + [em.arrival[r.rid] for r in batch])
        # per-request prefills, serialized on the device lane
        chunk = pol.prefill_chunk
        tail: List[Task] = []
        t_run = t_adm
        for r in batch:
            parents = [adm]
            last = None
            for c0, n in _chunks(r.prompt_tokens, chunk):
                dur = cost.prefill_time(n)
                last = em.prefill(r, n, dur, parents, chunk=c0)
                parents = []        # lane order chains further chunks
                t_run += dur
            tail.append(last)
        # lockstep decode: every step reads the batch's full pre-allocated
        # KV, so all ``budget`` steps cost the same (the drain invariant)
        budget = max(r.output_tokens for r in batch)
        kv = sum(r.prompt_tokens + r.output_tokens for r in batch)
        step_dur = cost.decode_step_time(len(batch), kv)
        excess = max(0.0, kv - cap) if pol.kv_offload else 0.0
        gate = em.gate(f"step:b{batches - 1}:s0", tail)
        for s in range(budget):
            toks = [em.token(r, k, s, step_dur, gate)
                    for k, r in enumerate(batch) if s < r.output_tokens]
            extra: List[Task] = []
            if pol.tp_degree > 1:
                extra.append(em.collective(
                    f"tp-ar:b{batches - 1}:s{s}",
                    len(toks) * cost.tp_coll_bytes_per_token,
                    em.step_coll_time(len(toks)), toks))
            if excess > 0:
                extra.append(em.dma(f"kv-dma:b{batches - 1}:s{s}", excess,
                                    cost.kv_offload_time(excess), toks))
            em.num_steps += 1
            t_run += step_dur + max(em.step_coll_time(len(toks)),
                                    cost.kv_offload_time(excess))
            gate = em.gate(f"step:b{batches - 1}:s{s + 1}", toks + extra)
        prev_gate = gate
        t_free = t_run
    return batches


def _chunks(tokens: int, chunk: int) -> List[Tuple[int, int]]:
    """(index, size) chunks of a prompt (one chunk when chunking is off)."""
    if chunk <= 0 or tokens <= chunk:
        return [(-1, tokens)]
    out = []
    done = 0
    i = 0
    while done < tokens:
        n = min(chunk, tokens - done)
        out.append((i, n))
        done += n
        i += 1
    return out


# ------------------------------------------------------------ continuous
@dataclasses.dataclass
class _Active:
    """One in-flight request of the continuous loop."""

    req: RequestSpec
    slot: int
    emitted: int = 0                # decode tokens emitted so far
    # remaining prefill chunks: (chunk index, tokens); empty == decoding
    chunks: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    last_work: Optional[Task] = None   # task the next step gate waits on


def _continuous_loop(em: _Emitter, wl: Workload) -> int:
    """Continuous batching: admit into free slots at every step boundary.

    Un-chunked prefills run on the device lane *before* the next step's
    gate — admitting a long prompt stalls every active decode (the classic
    TTFT interference chunked prefill removes).  With ``prefill_chunk``
    set, one chunk per admitted request rides along each decode step: the
    chunk task runs on the device lane in parallel with the step's token
    tasks and the next gate waits on both, so the step costs
    ``max(decode_step, chunk_time)`` instead of their sum.
    """
    pol, cost = em.pol, em.cost
    cap = pol.capacity(cost)
    pending: List[RequestSpec] = list(wl.requests)
    active: List[_Active] = []
    free_slots = list(range(pol.slots - 1, -1, -1))   # pop() -> slot 0 first
    reserved = 0.0
    t_now = 0.0
    prev_gate: Optional[Task] = None
    step_idx = 0
    while pending or active:
        if not active and pending and t_now < pending[0].arrival:
            t_now = pending[0].arrival      # idle engine: jump to arrival
        # --- admission at the step boundary ---------------------------
        admitted: List[_Active] = []
        while pending and free_slots and pending[0].arrival <= t_now:
            r = pending[0]
            need = r.prompt_tokens + r.output_tokens
            if reserved > 0 and not pol.kv_offload \
                    and reserved + need > cap:
                break               # FIFO head blocks until KV frees up
            pending.pop(0)
            a = _Active(req=r, slot=free_slots.pop(),
                        chunks=_chunks(r.prompt_tokens, pol.prefill_chunk))
            reserved += need
            active.append(a)
            admitted.append(a)
        # --- un-chunked prefills stall the engine before the next gate
        gate_parents: List[Task] = [prev_gate] if prev_gate else []
        seen = {t.uid for t in gate_parents}
        for a in admitted:
            parents = [em.arrival[a.req.rid]]
            if pol.prefill_chunk <= 0:
                (_, n), = a.chunks
                dur = cost.prefill_time(n)
                if prev_gate is not None:
                    parents.append(prev_gate)   # after the running step
                a.last_work = em.prefill(a.req, n, dur, parents)
                a.chunks = []
                t_now += dur
            else:
                a.last_work = parents[0]    # first chunk rides the step
        decoding = [a for a in active if not a.chunks]
        chunking = [a for a in active if a.chunks]
        if not decoding and not chunking:   # safety: cannot happen, but
            if pending:                     # never spin without progress
                t_now = max(t_now, pending[0].arrival)
                continue
            break
        # --- one engine step ------------------------------------------
        for a in active:
            if a.last_work is not None and a.last_work.uid not in seen:
                gate_parents.append(a.last_work)
                seen.add(a.last_work.uid)
        gate = em.gate(f"step:s{step_idx}", gate_parents)
        kv = sum(a.req.prompt_tokens + min(a.emitted, a.req.output_tokens)
                 for a in decoding) \
            + sum(a.req.prompt_tokens - sum(n for _, n in a.chunks)
                  for a in chunking)
        step_dur = cost.decode_step_time(len(decoding), kv) if decoding \
            else 0.0
        step_work: List[Task] = []
        chunk_time = 0.0            # chunks serialize on the device lane
        for a in chunking:          # one prefill chunk rides this step
            ci, n = a.chunks.pop(0)
            dur = cost.prefill_time(n)
            a.last_work = em.prefill(a.req, n, dur, [gate], chunk=ci)
            step_work.append(a.last_work)
            chunk_time += dur
        toks: List[Task] = []
        for a in decoding:
            a.last_work = em.token(a.req, a.slot, a.emitted, step_dur, gate)
            a.emitted += 1
            toks.append(a.last_work)
            step_work.append(a.last_work)
        coll_t = 0.0
        if pol.tp_degree > 1 and step_work:
            coll_t = em.step_coll_time(max(len(toks), 1))
            step_work.append(em.collective(
                f"tp-ar:s{step_idx}",
                max(len(toks), 1) * cost.tp_coll_bytes_per_token,
                coll_t, list(step_work)))
        excess = max(0.0, reserved - cap) if pol.kv_offload else 0.0
        dma_t = 0.0
        if excess > 0:
            dma_t = cost.kv_offload_time(excess)
            step_work.append(em.dma(f"kv-dma:s{step_idx}", excess, dma_t,
                                    list(toks) or list(step_work)))
        if toks:
            em.num_steps += 1
        t_now += max(step_dur, chunk_time) + max(coll_t, dma_t)
        step_idx += 1
        prev_gate = gate
        # --- retire drained requests ----------------------------------
        done = [a for a in decoding if a.emitted >= a.req.output_tokens]
        for a in done:
            active.remove(a)
            free_slots.append(a.slot)
            reserved -= a.req.prompt_tokens + a.req.output_tokens
        free_slots.sort(reverse=True)
    return 1
