"""Tiny timing harness: fit ServingCostModel constants from the seed engine.

Runs the seed :class:`repro.serve.ServeEngine`'s *jitted* prefill and
decode steps (the exact compiled functions the real engine loops over) a
handful of times, measures wall-clock, and solves the analytic
:class:`~repro.serving.costs.ServingCostModel` rooflines for
``prefill_scale`` / ``decode_scale`` — the same measure-once/reuse-forever
contract as ``CostModel.with_constants``: the harness prints the
``ServingCostModel.from_model_config(...).with_constants({...})`` line to
paste into :data:`repro.configs.serving.SERVING_COSTS`.

Usage (CPU-friendly on the smoke configs)::

    python -m repro.serving.measure --arch tinyllama-1.1b --smoke

Constants are fitted against whatever backend jax runs on; the per-arch
defaults shipped in :mod:`repro.configs.serving` were seeded with this
harness on the smoke configs.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Tuple

from repro.core.task import TPU_V5E, HardwareSpec
from .costs import ServingCostModel


def _time(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock of ``fn(*args)`` with block_until_ready."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]


def measure_serving_costs(arch: str = "tinyllama-1.1b", *,
                          smoke: bool = True, prompt_tokens: int = 32,
                          batch: int = 2, max_seq: int = 64,
                          hw: HardwareSpec = TPU_V5E
                          ) -> Tuple[ServingCostModel, Dict[str, float]]:
    """Measure the jitted prefill/decode of ``arch``'s (smoke) config and
    return the fitted model plus the constants mapping.

    The fit solves each roofline for its scale with the fixed per-step
    overhead pinned to ``hw.host_dispatch``::

        scale = (measured - overhead) / roofline(shape)

    which is exact for one measurement per kernel — the harness's job is a
    sane default, not a regression; :mod:`repro.analysis.calibrate`-style
    trace fitting can refine it later.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.serve.engine import ServeEngine
    from repro.models.model import build_model

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=max_seq)

    toks = jnp.asarray(np.ones((batch, prompt_tokens), np.int32))
    t_prefill = _time(eng._prefill, params, {"tokens": toks})
    logits, cache = eng._prefill(params, {"tokens": toks})
    cache = eng._grow_cache(cache, prompt_tokens)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(prompt_tokens, jnp.int32)
    t_decode = _time(lambda: eng._decode(params, cache, nxt, pos))

    # analytic model for the *measured* config, so the rooflines match
    # the shapes we actually ran
    analytic = ServingCostModel.from_model_config(cfg, hw)
    overhead = hw.host_dispatch
    pf_roof = (analytic.prefill_time(prompt_tokens) - analytic.step_overhead
               ) / analytic.prefill_scale
    kv = batch * prompt_tokens
    dc_roof = (analytic.decode_step_time(batch, kv) - analytic.step_overhead
               ) / analytic.decode_scale
    consts = {
        "prefill_scale": max(1e-3, (t_prefill - overhead) / pf_roof),
        "decode_scale": max(1e-3, (t_decode - overhead) / dc_roof),
        "step_overhead": overhead,
    }
    return analytic.with_constants(consts), consts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fit ServingCostModel constants from the seed "
                    "ServeEngine's jitted prefill/decode wall-clock")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="measure the CPU-sized smoke config")
    ap.add_argument("--prompt-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)
    fitted, consts = measure_serving_costs(
        args.arch, smoke=args.smoke, prompt_tokens=args.prompt_tokens,
        batch=args.batch)
    c = ", ".join(f"{k!r}: {v:.6g}" for k, v in consts.items())
    print(f"# measured {args.arch}"
          f"{' (smoke config)' if args.smoke else ''}; reuse with:")
    print(f"ServingCostModel.from_model_config("
          f"get_config({args.arch!r})).with_constants({{{c}}})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
