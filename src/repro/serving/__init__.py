"""Serving-scenario simulation: request-level workloads through the
dependency-graph what-if engine (ROADMAP item 3).

Daydream's thesis — estimate an optimization's efficacy by simulating its
effect on a dependency graph instead of implementing it — applied to
inference serving: an open-loop workload (:mod:`~repro.serving.workload`)
is lowered under a batching policy into a task graph
(:mod:`~repro.serving.graphgen`) priced by an analytic/fitted
:class:`ServingCostModel` (:mod:`~repro.serving.costs`), and
:class:`ServingScenario` (:mod:`~repro.serving.scenario`) routes it
through the *existing* registry/sweep machinery, so

    ServingScenario(workload=wl, serving_cost=cost).predict(
        "continuous_batching,chunked_prefill,tp:degree=8")

answers "what happens to my p99 TTFT and goodput" before anyone
implements the policy — with ``.critical_path`` diagnosis, trace
export/diff, and headroom bounds working unchanged on the serving graph.

The subsystem's calibration anchor is the **static-batch drain-time
invariant**: in ``mode="static"`` (seed ``repro/serve.ServeEngine``
semantics) a single full batch arriving at t=0 simulates to exactly
``sum(prefill_i) + budget * decode_step`` — see
:mod:`repro.serving.graphgen` for the full statement.

Importing this package registers the serving optimizations
(``continuous_batching``, ``static_slots``, ``chunked_prefill``, ``tp``,
``kv_offload``) with the global registry.
"""

from .workload import (RequestSpec, Workload, explicit_workload,
                       poisson_workload, scale_arrivals, trace_workload)
from .costs import ServingCostModel
from .graphgen import (ServingGraph, ServingPolicy, build_serving_graph,
                       slot_lane, slot_lane_classes, ARRIVAL_LANE, COLL_LANE, DMA_LANE,
                       SCHED_LANE)
from .scenario import (ChunkedPrefill, ContinuousBatching, KVOffload,
                       ServingOptimization, ServingPrediction,
                       ServingScenario, StaticSlots, TensorParallelServing,
                       format_serving_table, serving_metrics)

__all__ = [
    "RequestSpec", "Workload", "poisson_workload", "trace_workload",
    "explicit_workload", "scale_arrivals",
    "ServingCostModel",
    "ServingGraph", "ServingPolicy", "build_serving_graph", "slot_lane",
    "slot_lane_classes",
    "ARRIVAL_LANE", "SCHED_LANE", "COLL_LANE", "DMA_LANE",
    "ServingOptimization", "ContinuousBatching", "StaticSlots",
    "ChunkedPrefill", "TensorParallelServing", "KVOffload",
    "ServingScenario", "ServingPrediction", "serving_metrics",
    "format_serving_table",
]
