from .pipeline import (SyntheticLM, make_batch, host_shard, Prefetcher,
                       batch_specs)

__all__ = ["SyntheticLM", "make_batch", "host_shard", "Prefetcher",
           "batch_specs"]
