"""Data pipeline: deterministic synthetic token streams, per-host sharding,
and background prefetch (double buffering).

The synthetic stream has *learnable* structure — ``next = (a*tok + b) mod V``
with flip noise — so end-to-end training examples show a real loss decrease,
not just throughput.  Each host materializes only its slice of the global
batch (``host_shard``); the Daydream data-loading task duration is derived
from the bytes this pipeline actually moves.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.models.model import ModelConfig


def host_shard(global_batch: int, host_id: int, n_hosts: int) -> slice:
    per = global_batch // n_hosts
    rem = global_batch % n_hosts
    start = host_id * per + min(host_id, rem)
    return slice(start, start + per + (1 if host_id < rem else 0))


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream with a learnable affine structure."""

    vocab: int
    seq_len: int
    batch: int                      # this host's slice of the global batch
    seed: int = 0
    noise: float = 0.05
    a: int = 5
    b: int = 131

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        for t in range(self.seq_len):
            nxt = (self.a * toks[:, t] + self.b) % self.vocab
            flip = rng.random(self.batch) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, self.batch), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(cfg: ModelConfig, *, seq_len: int, batch: int, step: int,
               seed: int = 0, kind: str = "train") -> Dict[str, np.ndarray]:
    """Family-aware synthetic batch (numpy, host-local)."""
    rng = np.random.default_rng((seed, step, 7))
    if cfg.family == "vlm":
        text = seq_len - cfg.n_patches
        lm = SyntheticLM(cfg.vocab, text, batch, seed)
        b = lm.batch_at(step)
        b["patch_embeds"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        b["patch_embeds"] = b["patch_embeds"].astype("bfloat16")
    elif cfg.family == "encdec":
        lm = SyntheticLM(cfg.vocab, seq_len, batch, seed)
        b = lm.batch_at(step)
        b["src_embeds"] = (rng.standard_normal(
            (batch, seq_len, cfg.d_model)).astype(np.float32) * 0.02
        ).astype("bfloat16")
    else:
        b = SyntheticLM(cfg.vocab, seq_len, batch, seed).batch_at(step)
    if kind != "train":
        b.pop("labels", None)
    return b


def batch_specs(cfg: ModelConfig, *, seq_len: int, global_batch: int,
                kind: str = "train"):
    """SpecLeaf stand-ins matching make_batch (delegates to models)."""
    from repro.models.model import input_specs
    return input_specs(cfg, kind=kind, seq_len=seq_len,
                       global_batch=global_batch)


class Prefetcher:
    """Background-thread double buffering over any batch iterator."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2) -> None:
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:   # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
