"""jax version compatibility shims.

The repo targets the modern jax mesh API (``jax.make_mesh(axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``, dict-returning ``cost_analysis``); the
container pins jax 0.4.x where those are absent or shaped differently.  All
call sites route through this module so the code runs on both:

* :func:`make_mesh` — forwards ``axis_types`` only when the installed jax
  understands it (0.4.x meshes are implicitly Auto, so dropping it is
  semantically equivalent).
* :func:`set_mesh` — ``jax.set_mesh`` when present, else the ``Mesh``
  object's own context manager (which installs the resource env that
  ``shard_map`` / sharding propagation read in 0.4.x).
* :func:`shard_map` — ``jax.shard_map`` or the experimental import.
* :func:`cost_analysis_dict` — XLA cost analysis as one flat dict (0.4.x
  returns a list with one dict per program).
"""

from __future__ import annotations

import inspect
from typing import Any, Dict, Optional, Sequence

import jax

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def axis_type_auto() -> Optional[Any]:
    """``jax.sharding.AxisType.Auto`` on new jax, None (implicit) on 0.4.x."""
    at = getattr(jax.sharding, "AxisType", None)
    return getattr(at, "Auto", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with every axis typed Auto where supported."""
    kw: Dict[str, Any] = {}
    if devices is not None:
        kw["devices"] = devices
    auto = axis_type_auto()
    if _MAKE_MESH_HAS_AXIS_TYPES and auto is not None:
        kw["axis_types"] = (auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is its own context manager on 0.4.x


def shard_map(*args, **kwargs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(*args, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(*args, **kwargs)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` to a flat dict (or {})."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    try:
        return dict(ca)
    except Exception:
        return {}
