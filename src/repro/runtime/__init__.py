from .fault import (FaultTolerantRunner, Heartbeat, StragglerMonitor,
                    RetryPolicy)

__all__ = ["FaultTolerantRunner", "Heartbeat", "StragglerMonitor",
           "RetryPolicy"]
