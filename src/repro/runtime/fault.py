"""Fault tolerance & straggler mitigation for the training runtime.

Production posture (DESIGN.md §6) mapped to testable components:

  * :class:`RetryPolicy` / :class:`FaultTolerantRunner` — run a step function
    under checkpoint/restart semantics: on failure, restore the latest
    committed checkpoint, rebuild device state (possibly on a *different*
    mesh — elastic), and continue.  Exceptions count against a failure
    budget; exceeding it re-raises (a real deployment would escalate to the
    cluster scheduler).
  * :class:`Heartbeat` — liveness file other processes/watchdogs can monitor
    (on a fleet this is the per-host health signal the coordinator watches).
  * :class:`StragglerMonitor` — per-step deadline tracking against a rolling
    median; flags slow steps and calls a mitigation hook (skip/rebalance).
    The matching Daydream query (`what_if_straggler`) predicts whether
    mitigation pays *before* enabling it.
"""

from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class RetryPolicy:
    max_failures: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0) -> None:
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self, step: int, **info) -> None:
        now = time.time()
        if now - self._last < self.interval_s:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"time": now, "step": step, **info}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, timeout_s: float = 60.0) -> bool:
        try:
            with open(path) as f:
                beat = json.load(f)
            return time.time() - beat["time"] < timeout_s
        except (OSError, ValueError, KeyError):
            return False


class StragglerMonitor:
    """Rolling-median step-time watchdog."""

    def __init__(self, threshold: float = 2.0, window: int = 32,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None) -> None:
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.times.append(dt)
        return is_straggler

    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class FaultTolerantRunner:
    """Checkpoint/restart wrapper around a stateful step loop.

    The caller supplies:
      * ``make_state()``      — build fresh state (init or restore),
      * ``step_fn(state, i)`` — one training step, returns new state,
      * ``save(state, i)``    — checkpoint hook,
      * ``restore()``         — returns (state, step) from the latest
                                committed checkpoint, or None.
    ``inject_failure`` lets tests (and chaos drills) raise at a chosen step.
    """

    def __init__(self, make_state: Callable[[], Any],
                 step_fn: Callable[[Any, int], Any],
                 save: Callable[[Any, int], None],
                 restore: Callable[[], Optional[tuple]],
                 policy: RetryPolicy = RetryPolicy(),
                 save_every: int = 50,
                 heartbeat: Optional[Heartbeat] = None,
                 straggler: Optional[StragglerMonitor] = None) -> None:
        self.make_state = make_state
        self.step_fn = step_fn
        self.save = save
        self.restore = restore
        self.policy = policy
        self.save_every = save_every
        self.heartbeat = heartbeat
        self.straggler = straggler or StragglerMonitor()
        self.failures = 0
        self.restarts = 0

    def run(self, num_steps: int,
            inject_failure: Optional[Callable[[int], None]] = None) -> Any:
        restored = self.restore()
        if restored is not None:
            state, start = restored
            start += 1
        else:
            state, start = self.make_state(), 0
        i = start
        backoff = self.policy.backoff_s
        while i < num_steps:
            try:
                if inject_failure is not None:
                    inject_failure(i)
                t0 = time.time()
                state = self.step_fn(state, i)
                self.straggler.record(i, time.time() - t0)
                if self.heartbeat:
                    self.heartbeat.beat(i)
                if (i + 1) % self.save_every == 0 or i + 1 == num_steps:
                    self.save(state, i)
                i += 1
                backoff = self.policy.backoff_s
            except Exception:
                self.failures += 1
                if self.failures > self.policy.max_failures:
                    raise
                time.sleep(backoff)
                backoff *= self.policy.backoff_mult
                restored = self.restore()
                if restored is not None:
                    state, last = restored
                    i = last + 1
                else:
                    state, i = self.make_state(), 0
                self.restarts += 1
        return state
