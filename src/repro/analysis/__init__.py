"""Diagnosis subsystem: critical paths, trace diffs, opportunity ranking.

The simulator (:mod:`repro.core.simulate`), the cluster graphs
(:mod:`repro.core.cluster`), and the trace I/O layer
(:mod:`repro.traceio`) predict *a* makespan; this package explains it:

* :mod:`repro.analysis.critical_path` — walk the recorded binding
  predecessors (``simulate(record_binding=True)``) to the
  makespan-defining chain and attribute it into compute / comm / host /
  idle, per worker.
* :mod:`repro.analysis.diff` — align a captured per-worker trace against
  the predicted timeline task-by-task (paper §6 validation methodology as
  a reusable tool): per-task error distributions, per-kind rollups, top-K
  mispredictions.
* :mod:`repro.analysis.opportunity` — Amdahl-style speedup upper bounds
  per registered optimization, computed through the real simulator, which
  is the ordering ``hillclimb --search-whatif`` explores.
* :mod:`repro.analysis.calibrate` — close the fidelity loop: fit CostModel
  constants (per-kind duration scales, link-bandwidth factors, hop
  latency) to a captured trace by iterating simulate → diff → refit
  through the real simulator (dPRO's trace-fitted replayer).

User surfaces: ``python -m repro.launch.diagnose --trace-dir DIR
[--calibrate]``, ``python -m repro.launch.calibrate --trace-dir DIR``,
``perf_report --critical-path``, ``Prediction.critical_path``,
``Scenario.diff_against(trace_dir)``, and ``Scenario.calibrate()``.
"""

from .calibrate import CalibrationReport, calibrate_scenario
from .critical_path import (CATEGORIES, CriticalPath, PathSegment,
                            cluster_critical_path, extract_critical_path)
from .diff import (KindStats, TaskDiff, TraceDiff, diff_cluster, diff_graph,
                   diff_prediction, diff_worker_events)
from .opportunity import (NO_HEADROOM, Opportunity, format_opportunity_table,
                          opportunity_bound, rank_opportunities,
                          searchable_candidates)

__all__ = [
    "CalibrationReport", "calibrate_scenario",
    "CATEGORIES", "CriticalPath", "PathSegment",
    "cluster_critical_path", "extract_critical_path",
    "KindStats", "TaskDiff", "TraceDiff",
    "diff_cluster", "diff_graph", "diff_prediction", "diff_worker_events",
    "NO_HEADROOM", "Opportunity", "format_opportunity_table",
    "opportunity_bound", "rank_opportunities", "searchable_candidates",
]
