"""Predicted-vs-captured trace diffing: *where* does the model disagree.

Daydream's validation methodology (paper §6) compares predicted runtimes
against ground-truth captures; dPRO refines it to per-op error
attribution.  This module turns that methodology into a reusable tool: the
predicted timeline is rendered per worker exactly as the trace exporter
writes it (:func:`repro.traceio.predicted_worker_events` — collectives
collapsed to one per-worker event, p2p hops with provenance), the captured
per-worker trace is clock-aligned (:mod:`repro.traceio.align`) and rebased
to t=0, and the two sides are matched task-by-task:

* primary key **(lane, name, occurrence)** — workers run the same program,
  so the k-th same-named event on a thread is the same logical operation
  (the discipline collective matching already uses);
* a second pass rescues renamed/re-homed events through *provenance*:
  collectives by ``coll_gid``, p2p hop legs by ``p2p_gid`` — exact for
  traces this repo exported, inert for foreign captures (gids simply
  absent on one side).

The output is the per-task error distribution, per-kind rollups, and a
top-K "most mispredicted tasks" report — what
``python -m repro.launch.diagnose`` and ``Scenario.diff_against`` print.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import DependencyGraph
from repro.core.simulate import SimResult


@dataclasses.dataclass(frozen=True)
class TaskDiff:
    """One matched (predicted, captured) task pair."""

    worker: int
    thread: str
    name: str
    occurrence: int
    kind: str
    predicted_start: float
    predicted_dur: float
    captured_start: float
    captured_dur: float

    @property
    def dur_error(self) -> float:
        """Signed duration error, seconds (positive == over-predicted)."""
        return self.predicted_dur - self.captured_dur

    @property
    def start_error(self) -> float:
        """Signed timeline-placement error, seconds."""
        return self.predicted_start - self.captured_start

    @property
    def abs_error(self) -> float:
        """Worst of |duration error| and |start error| — the "how wrong is
        this task" scalar the top-K report ranks by."""
        return max(abs(self.dur_error), abs(self.start_error))

    @property
    def rel_dur_error(self) -> float:
        """|duration error| relative to the captured duration (inf for a
        predicted-from-nothing duration)."""
        if self.captured_dur > 0:
            return abs(self.dur_error) / self.captured_dur
        return 0.0 if self.predicted_dur == 0 else float("inf")


@dataclasses.dataclass
class KindStats:
    """Error rollup for one task kind."""

    count: int = 0
    captured_s: float = 0.0
    predicted_s: float = 0.0
    abs_err_s: float = 0.0        # summed |duration error|
    max_abs_err_s: float = 0.0

    @property
    def wape(self) -> float:
        """Weighted absolute percentage error of durations (sum|err| /
        sum captured) — the per-kind headline number."""
        if self.captured_s > 0:
            return self.abs_err_s / self.captured_s
        return 0.0 if self.abs_err_s == 0 else float("inf")


@dataclasses.dataclass
class TraceDiff:
    """Full predicted-vs-captured comparison."""

    tasks: List[TaskDiff]
    unmatched_predicted: List[Tuple[int, str, str, int]]  # (w, thread, name, occ)
    unmatched_captured: List[Tuple[int, str, str, int]]
    predicted_makespan: float
    captured_makespan: float

    @property
    def makespan_error(self) -> float:
        return self.predicted_makespan - self.captured_makespan

    @property
    def makespan_rel_error(self) -> float:
        if self.captured_makespan > 0:
            return self.makespan_error / self.captured_makespan
        return 0.0 if self.predicted_makespan == 0 else float("inf")

    def max_abs_error(self) -> float:
        """Largest per-task error in the whole diff (0.0 when empty) —
        the round-trip invariant asserts this is ~0 when a prediction is
        diffed against its own export."""
        return max((d.abs_error for d in self.tasks), default=0.0)

    def per_kind(self) -> Dict[str, KindStats]:
        out: Dict[str, KindStats] = collections.defaultdict(KindStats)
        for d in self.tasks:
            st = out[d.kind]
            st.count += 1
            st.captured_s += d.captured_dur
            st.predicted_s += d.predicted_dur
            err = abs(d.dur_error)
            st.abs_err_s += err
            if err > st.max_abs_err_s:
                st.max_abs_err_s = err
        return dict(out)

    def top_mispredicted(self, k: int = 10) -> List[TaskDiff]:
        """The ``k`` worst-predicted tasks, by :attr:`TaskDiff.abs_error`
        (non-finite errors excluded — they rank by :meth:`format`'s n/a
        rows, not here)."""
        finite = [d for d in self.tasks if math.isfinite(d.abs_error)]
        return sorted(finite, key=lambda d: -d.abs_error)[:k]

    # ------------------------------------------------------------- report
    def format(self, *, top: int = 10, unit: float = 1e3,
               unit_name: str = "ms") -> str:
        lines = [f"== predicted vs captured: {len(self.tasks)} matched "
                 f"task(s), {len(self.unmatched_predicted)} unmatched "
                 f"predicted, {len(self.unmatched_captured)} unmatched "
                 f"captured =="]
        lines.append(
            f"makespan: predicted {self.predicted_makespan * unit:.3f} "
            f"{unit_name} vs captured {self.captured_makespan * unit:.3f} "
            f"{unit_name} ({_pct(self.makespan_rel_error, signed=True)})")
        kinds = self.per_kind()
        if kinds:
            lines.append(f"{'kind':12s} {'count':>6s} {'captured':>10s} "
                         f"{'predicted':>10s} {'wape':>7s} {'max|err|':>9s}")
            for kind in sorted(kinds):
                st = kinds[kind]
                lines.append(
                    f"{kind:12s} {st.count:6d} "
                    f"{st.captured_s * unit:10.3f} "
                    f"{st.predicted_s * unit:10.3f} "
                    f"{_pct(st.wape):>7s} "
                    f"{st.max_abs_err_s * unit:9.4f}")
        worst = [d for d in self.top_mispredicted(top) if d.abs_error > 0]
        if worst:
            lines.append(f"top {len(worst)} mispredicted task(s):")
            for d in worst:
                lines.append(
                    f"  w{d.worker} {d.thread:16s} {d.name}#{d.occurrence}: "
                    f"dur {d.predicted_dur * unit:.4f} vs "
                    f"{d.captured_dur * unit:.4f} {unit_name} "
                    f"({d.dur_error * unit:+.4f}), start "
                    f"{d.start_error * unit:+.4f}")
        return "\n".join(lines)


def _pct(x: float, *, signed: bool = False) -> str:
    """Render a ratio as a percentage; ``n/a`` for non-finite values
    (a zero-captured denominator has no meaningful relative error)."""
    if not math.isfinite(x):
        return "n/a"
    return f"{x * 100:+.2f}%" if signed else f"{x * 100:.2f}%"


# =============================================================== matching
def _keyed(events) -> Dict[Tuple[str, str, int], Any]:
    """(thread, name, occurrence) -> event, occurrence counted in
    (thread, ts) scan order — deterministic for any event file order."""
    seen: Dict[Tuple[str, str], int] = collections.defaultdict(int)
    out: Dict[Tuple[str, str, int], Any] = {}
    for ev in sorted(events, key=lambda e: (e.thread, e.ts, e.eid)):
        k = (ev.thread, ev.name)
        out[(ev.thread, ev.name, seen[k])] = ev
        seen[k] += 1
    return out


def _gid_of(ev) -> Optional[Tuple[str, int]]:
    """Provenance identity of an event, when it carries one."""
    gid = ev.attrs.get("coll_gid")
    if gid is not None:
        return ("coll", int(gid))
    gid = ev.attrs.get("p2p_gid")
    if gid is not None:
        return ("p2p", int(gid))
    return None


def diff_worker_events(predicted, captured, worker: int
                       ) -> Tuple[List[TaskDiff], List[Tuple], List[Tuple]]:
    """Match one worker's predicted events against its captured events.

    Primary match by (thread, name, occurrence); leftover events on both
    sides get a provenance pass (``coll_gid`` / ``p2p_gid``) so renamed or
    re-homed collectives and hops still pair up.  Returns ``(diffs,
    unmatched_predicted_keys, unmatched_captured_keys)``.
    """
    pk, ck = _keyed(predicted), _keyed(captured)
    diffs: List[TaskDiff] = []
    matched_c = set()

    def emit(key, pev, cev):
        diffs.append(TaskDiff(
            worker=worker, thread=key[0], name=pev.name, occurrence=key[2],
            kind=pev.kind or "?", predicted_start=pev.ts,
            predicted_dur=pev.dur, captured_start=cev.ts,
            captured_dur=cev.dur))

    leftover_p = []
    for key, pev in pk.items():
        cev = ck.get(key)
        if cev is not None:
            matched_c.add(key)
            emit(key, pev, cev)
        else:
            leftover_p.append((key, pev))
    leftover_c = {k: ev for k, ev in ck.items() if k not in matched_c}

    # provenance pass over the leftovers
    by_gid_c = {}
    for k, ev in leftover_c.items():
        gid = _gid_of(ev)
        if gid is not None:
            by_gid_c[gid] = (k, ev)
    unmatched_p = []
    for key, pev in leftover_p:
        gid = _gid_of(pev)
        hit = by_gid_c.pop(gid, None) if gid is not None else None
        if hit is not None:
            ckey, cev = hit
            del leftover_c[ckey]
            emit(key, pev, cev)
        else:
            unmatched_p.append((worker,) + key)
    unmatched_c = [(worker,) + k for k in leftover_c]
    return diffs, unmatched_p, unmatched_c


# ============================================================== entry points
def _captured_makespan(events) -> float:
    """Last completion across events, gaps included — the predicted side's
    ``SimResult.makespan`` is ``finish + gap`` of the last task, so the
    captured side must account trailing untraced time the same way or the
    headline makespan error carries a systematic bias."""
    return max((ev.end + (ev.gap or 0.0) for ev in events), default=0.0)


def _load_captured(captured, n_workers: int):
    """Captured side -> (per-worker rebased event lists, makespan)."""
    from repro.traceio import ImportedCluster, load_trace_dir
    if not isinstance(captured, ImportedCluster):
        captured = load_trace_dir(str(captured))
    if captured.num_workers != n_workers:
        raise ValueError(
            f"predicted timeline has {n_workers} worker(s) but the captured "
            f"trace set has {captured.num_workers}")
    events = captured.worker_events(rebase=True)
    return events, _captured_makespan(
        [ev for evs in events for ev in evs])


def diff_cluster(cluster_graph, result, captured) -> TraceDiff:
    """Diff a simulated cluster against a captured per-worker trace set.

    ``result`` is the :class:`~repro.core.cluster.ClusterResult` of the
    prediction; ``captured`` is a trace directory or a pre-loaded
    :class:`repro.traceio.ImportedCluster` (clock-aligned on load).  Both
    sides are rendered as per-worker profiler-shaped timelines, so
    collectives compare as one event per worker and p2p hops compare
    leg-for-leg — diffing a prediction against its *own* export reports
    zero error for every task, the subsystem's round-trip invariant.
    """
    from repro.traceio import predicted_worker_events
    pred_events = predicted_worker_events(cluster_graph, result)
    cap_events, cap_makespan = _load_captured(captured, len(pred_events))
    res = getattr(result, "global_result", result)
    return _assemble_diff(
        [(pred_events[w], cap_events[w]) for w in range(len(pred_events))],
        res.makespan, cap_makespan)


def diff_graph(graph: DependencyGraph, result: SimResult,
               captured) -> TraceDiff:
    """Single-worker form: diff one simulated graph against one captured
    trace (a :class:`repro.traceio.WorkerTrace`, a trace file path, or a
    one-worker trace directory)."""
    from repro.traceio import WorkerTrace, events_from_graph, \
        load_worker_trace
    import os
    if isinstance(captured, WorkerTrace):
        trace = captured
    elif os.path.isdir(str(captured)):
        events, makespan = _load_captured(captured, 1)
        pred = events_from_graph(graph, result)
        return _assemble_diff([(pred, events[0])], result.makespan, makespan)
    else:
        trace = load_worker_trace(str(captured))
    t0 = trace.first_ts()
    cap = [dataclasses.replace(ev, ts=ev.ts - t0) for ev in trace.events]
    cap_makespan = _captured_makespan(cap)
    pred = events_from_graph(graph, result)
    return _assemble_diff([(pred, cap)], result.makespan, cap_makespan)


def diff_prediction(pred, tf, cg, captured) -> TraceDiff:
    """Diff an evaluated prediction (the ``(pred, tf, cg)`` triple
    :meth:`Scenario.evaluate` returns) against a captured trace set —
    cluster routes compare per worker, single-graph routes compare the one
    timeline."""
    if cg is not None:
        return diff_cluster(cg, pred.cluster, captured)
    return diff_graph(tf.graph, pred.result, captured)


def _assemble_diff(pairs: Sequence[Tuple[list, list]],
                   predicted_makespan: float,
                   captured_makespan: float) -> TraceDiff:
    tasks: List[TaskDiff] = []
    up: List[Tuple] = []
    uc: List[Tuple] = []
    for w, (pev, cev) in enumerate(pairs):
        d, p, c = diff_worker_events(pev, cev, w)
        tasks.extend(d)
        up.extend(p)
        uc.extend(c)
    return TraceDiff(tasks=tasks, unmatched_predicted=up,
                     unmatched_captured=uc,
                     predicted_makespan=predicted_makespan,
                     captured_makespan=captured_makespan)
