"""Diff-driven CostModel auto-calibration: close the fidelity loop.

PR 5's :meth:`Scenario.diff_against` measures *where* the simulator
disagrees with a capture; this module consumes that error signal the way
dPRO (arXiv:2205.02473) earns its <5% fidelity — by fitting the replayer's
constants to the trace.  The loop is simulate → diff → refit, always
through the *real* simulator (one :class:`ClusterGraph` build, then
cost-swap + :meth:`ClusterGraph.retune` per probe, so a probe costs one
retune+simulate, never a rebuild):

* **per-kind duration scales** (``kind_scale:compute`` ...) have a
  closed-form coordinate update: diff matching keys on (lane, name,
  occurrence), which graph program order keeps stable under duration
  changes, so predicted durations of kind *k* respond *linearly* to its
  scale and the L1-optimal multiplier is the predicted-duration-weighted
  median of captured/predicted ratios.  Each proposal is verified through
  the simulator and accepted only if the global loss drops — the loss
  history is monotone by construction.
* **link constants** (``ici_factor``, ``dcn_factor``, ``hop_latency``)
  shape collective/p2p durations non-separably (ring legs couple workers,
  blocking time folds in), so they are fit by bounded golden-section
  search on ``log10(value)``, again accept-only-if-improved.

The loss is the global duration WAPE (sum |predicted - captured| over the
matched tasks / sum captured) — the same per-kind number
:meth:`TraceDiff.format` reports, rolled up.

Entry points: :func:`calibrate_scenario` (drives
:meth:`repro.core.optimize.Scenario.calibrate`) and the CLI surfaces
``python -m repro.launch.calibrate --trace-dir`` / ``diagnose
--calibrate``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import CostModel, FittableConstant
from repro.obs.spans import span as _obs_span

from .diff import TraceDiff, diff_cluster

# Kinds whose durations the link constants (not per-kind scales) explain.
_LINK_KINDS = ("collective", "comm")

_GOLDEN = (math.sqrt(5.0) - 1.0) / 2.0


@dataclasses.dataclass
class CalibrationReport:
    """What one calibration run did, before/after fidelity included."""

    before: TraceDiff
    after: TraceDiff
    fitted: Dict[str, Tuple[float, float]]   # name -> (initial, fitted)
    loss_history: List[float]                # global WAPE per accepted state
    rounds: int
    sim_calls: int
    converged: bool

    @property
    def loss_before(self) -> float:
        return self.loss_history[0] if self.loss_history else 0.0

    @property
    def loss_after(self) -> float:
        return self.loss_history[-1] if self.loss_history else 0.0

    def format(self, *, unit: float = 1e3, unit_name: str = "ms") -> str:
        """The before/after fidelity table (per-kind WAPE, makespan)."""
        from .diff import _pct
        lines = [f"== calibration: {self.rounds} round(s), "
                 f"{self.sim_calls} simulator call(s), loss "
                 f"{_pct(self.loss_before)} -> {_pct(self.loss_after)}"
                 f"{' (converged)' if self.converged else ''} =="]
        bk, ak = self.before.per_kind(), self.after.per_kind()
        lines.append(f"{'kind':12s} {'count':>6s} {'captured':>10s} "
                     f"{'wape before':>12s} {'wape after':>11s}")
        for kind in sorted(set(bk) | set(ak)):
            b, a = bk.get(kind), ak.get(kind)
            cap = (b or a).captured_s
            cnt = (b or a).count
            lines.append(
                f"{kind:12s} {cnt:6d} {cap * unit:10.3f} "
                f"{_pct(b.wape) if b else 'n/a':>12s} "
                f"{_pct(a.wape) if a else 'n/a':>11s}")
        lines.append(
            f"makespan rel err: "
            f"{_pct(self.before.makespan_rel_error, signed=True)} -> "
            f"{_pct(self.after.makespan_rel_error, signed=True)} "
            f"(captured {self.before.captured_makespan * unit:.3f} "
            f"{unit_name})")
        changed = {n: v for n, v in self.fitted.items()
                   if not math.isclose(v[0], v[1], rel_tol=1e-9)}
        if changed:
            lines.append("fitted constants:")
            for name in sorted(changed):
                init, fit = changed[name]
                lines.append(f"  {name:24s} {init:.6g} -> {fit:.6g}")
        else:
            lines.append("fitted constants: none moved (model already "
                         "at a loss minimum)")
        return "\n".join(lines)


def _loss(diff: TraceDiff) -> float:
    """Global duration WAPE over the matched tasks."""
    cap = sum(d.captured_dur for d in diff.tasks)
    err = sum(abs(d.dur_error) for d in diff.tasks)
    if cap > 0:
        return err / cap
    return 0.0 if err == 0 else float("inf")


def _weighted_median_ratio(pairs: Sequence[Tuple[float, float]]) -> float:
    """Predicted-duration-weighted median of captured/predicted ratios —
    the exact L1 minimizer of ``sum |s * pred - cap|`` over ``s``.

    ``pairs`` is (predicted, captured) per matched task; zero-predicted
    tasks carry no weight (no scale can move them) and are skipped.
    """
    ratios = sorted((cap / pred, pred) for pred, cap in pairs if pred > 0)
    if not ratios:
        return 1.0
    total = sum(w for _, w in ratios)
    acc = 0.0
    for ratio, w in ratios:
        acc += w
        if acc >= total / 2.0:
            return ratio
    return ratios[-1][0]


class _Evaluator:
    """simulate+diff at a candidate cost, through one reusable cluster.

    Builds the trace cluster once, then evaluates each candidate CostModel
    by swapping ``cluster.cost`` and retuning — the exact durations a
    fresh build would produce (``retune``'s contract), at a fraction of
    the cost.  Counts simulator calls and memoizes by constant vector so
    repeated probes (golden-section endpoints, closed-form verification)
    are free.
    """

    def __init__(self, scenario, imported) -> None:
        self.scenario = scenario
        self.imported = imported
        self.cluster = scenario._trace_cluster(imported.graphs)
        self.sim_calls = 0
        self._memo: Dict[Any, Tuple[float, TraceDiff]] = {}

    def __call__(self, cost: CostModel) -> Tuple[float, TraceDiff]:
        key = (tuple(sorted(cost.kind_scales.items())), cost.ici_factor,
               cost.dcn_factor, cost.collectives.hop_latency)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        cg = self.cluster
        cg.cost = cost
        cg.retune(cg.workers)
        res = cg.simulate()
        diff = diff_cluster(cg, res, self.imported)
        self.sim_calls += 1
        out = (_loss(diff), diff)
        self._memo[key] = out
        return out


def _golden_section(evaluate, lo: float, hi: float, probes: int
                    ) -> Tuple[float, float]:
    """Minimize ``evaluate(x)`` over ``[lo, hi]`` in log10 space with at
    most ``probes`` evaluations; returns (best_x, best_loss)."""
    a, b = math.log10(lo), math.log10(hi)
    x1 = b - _GOLDEN * (b - a)
    x2 = a + _GOLDEN * (b - a)
    f1, f2 = evaluate(10 ** x1), evaluate(10 ** x2)
    best_x, best_f = (x1, f1) if f1 <= f2 else (x2, f2)
    for _ in range(max(0, probes - 2)):
        if f1 <= f2:
            b, x2, f2 = x2, x1, f1
            x1 = b - _GOLDEN * (b - a)
            f1 = evaluate(10 ** x1)
        else:
            a, x1, f1 = x1, x2, f2
            x2 = a + _GOLDEN * (b - a)
            f2 = evaluate(10 ** x2)
        if f1 < best_f:
            best_x, best_f = x1, f1
        if f2 < best_f:
            best_x, best_f = x2, f2
    return 10 ** best_x, best_f


def calibrate_scenario(scenario, traces: Any = None, *,
                       constants: Optional[Sequence[str]] = None,
                       max_rounds: int = 6, tol: float = 1e-3,
                       probes_per_constant: int = 6
                       ) -> Tuple[Any, CalibrationReport]:
    """Fit ``scenario.cost``'s constants against a captured trace set.

    ``traces`` is a trace directory or pre-loaded
    :class:`repro.traceio.ImportedCluster`; it defaults to the scenario's
    own capture (``Scenario(trace_dir=...)``) — the dPRO workflow of
    fitting the replayer to the trace it replays.  ``constants`` names a
    subset of :meth:`CostModel.fittable_constants` to fit (default: every
    constant whose task kind / link actually appears in the diff).

    Returns ``(calibrated_scenario, CalibrationReport)``; the input
    scenario is never mutated.  The loop runs at most ``max_rounds``
    coordinate-descent rounds, each proposal verified through the real
    simulator and accepted only on improvement, and stops early once a
    round improves the loss by less than ``tol`` (relative).  Simulator
    calls are bounded by ``1 + rounds * constants * probes_per_constant``
    — the budget ``benchmarks/bench_analysis.py`` gates.
    """
    from repro.traceio import ImportedCluster, load_trace_dir
    if traces is None:
        traces = scenario.traces
    if traces is None:
        raise ValueError("calibrate needs a captured trace set: pass "
                         "traces/trace_dir or build the Scenario from one")
    if not isinstance(traces, ImportedCluster):
        traces = load_trace_dir(str(traces))

    base = scenario if scenario.traces is traces else \
        dataclasses.replace(scenario, traces=traces, trace_dir=None,
                            workers=1)
    evaluate = _Evaluator(base, traces)
    cost = base.cost
    loss, before = evaluate(cost)
    history = [loss]

    # fit only constants the capture can actually inform
    kinds_present = {d.kind for d in before.tasks}
    all_constants = {c.name: c for c in cost.fittable_constants(
        kinds=sorted(kinds_present - set(_LINK_KINDS)))}
    has_link = bool(kinds_present & set(_LINK_KINDS))
    if not has_link:
        for name in ("ici_factor", "dcn_factor", "hop_latency"):
            all_constants.pop(name, None)
    if scenario.collective_mode == "fused":
        # fused mode replays traced collective durations verbatim — the
        # link constants have nothing to move
        for name in ("ici_factor", "dcn_factor", "hop_latency"):
            all_constants.pop(name, None)
    if constants is not None:
        unknown = set(constants) - set(all_constants)
        if unknown:
            raise ValueError(
                f"unknown/unfittable constant(s) {sorted(unknown)}; "
                f"available here: {sorted(all_constants)}")
        all_constants = {n: all_constants[n] for n in constants}

    initial = {n: c.value for n, c in all_constants.items()}
    current = dict(initial)
    rounds = 0
    converged = False
    last_diff = before
    for _ in range(max_rounds):
        if history[-1] < 1e-9:     # already a faithful replay
            converged = True
            break
        rounds += 1
        round_start = history[-1]
        with _obs_span("calibrate.round", round=rounds,
                       constants=len(all_constants)) as sp:
            for name, const in all_constants.items():
                if const.kind is not None:
                    pairs = [(d.predicted_dur, d.captured_dur)
                             for d in last_diff.tasks
                             if d.kind == const.kind]
                    ratio = _weighted_median_ratio(pairs)
                    proposal = min(max(current[name] * ratio, const.lo),
                                   const.hi)
                    if math.isclose(proposal, current[name], rel_tol=1e-9):
                        continue
                    cand = cost.with_constants({**current, name: proposal})
                    cand_loss, cand_diff = evaluate(cand)
                    if cand_loss < history[-1]:
                        current[name] = proposal
                        cost = cand
                        history.append(cand_loss)
                        last_diff = cand_diff
                else:
                    def probe(x, _name=name):
                        return evaluate(
                            cost.with_constants({**current, _name: x}))[0]
                    best_x, best_f = _golden_section(
                        probe, const.lo, const.hi, probes_per_constant)
                    if best_f < history[-1] and not math.isclose(
                            best_x, current[name], rel_tol=1e-9):
                        current[name] = best_x
                        cost = cost.with_constants({name: best_x})
                        loss2, last_diff = evaluate(cost)
                        history.append(loss2)
            sp.note(loss=history[-1])
        improved = round_start - history[-1]
        if improved <= tol * max(round_start, 1e-12):
            converged = True
            break

    _, after = evaluate(cost)
    report = CalibrationReport(
        before=before, after=after,
        fitted={n: (initial[n], current[n]) for n in all_constants},
        loss_history=history, rounds=rounds,
        sim_calls=evaluate.sim_calls, converged=converged)
    calibrated = dataclasses.replace(scenario, cost=cost)
    return calibrated, report
