"""Opportunity ranking: *which* optimization is worth trying first.

For every registered :class:`~repro.core.optimize.Optimization` an
Amdahl-style **upper bound** on its speedup is computed through the real
simulator: the optimization declares which tasks it can shrink
(:meth:`Optimization.headroom_targets` / :meth:`Optimization.headroom`),
an idealized variant with those tasks erased (duration and payload to
zero) is evaluated on the scenario's own route — single graph, replicate
cluster, or imported traces — and the resulting speedup bounds anything
the real model can deliver.

Soundness: with lanes fixed, a task's start is the max over its
predecessors' completions, so the makespan is *monotone* in durations and
payloads.  Every registered optimization either shrinks (a subset of) its
declared targets or adds work elsewhere, so its realized speedup can never
exceed the bound — the invariant the golden test pins for the whole
registry.  Note the targets must be erased *everywhere*, not only on the
current critical path: shrinking on-path tasks exposes a new path that the
optimization may also shrink, so a path-restricted bound would not be an
upper bound.  The critical path still drives the *attribution* column —
how much of today's makespan the targets occupy — which is the fast signal
for why a bound is large.

Optimizations that restructure the graph instead of shrinking tasks
(``pipeline``) have no shrink-bound and rank as *unbounded* (try early,
the ranking cannot rule them out); optimizations that only add work
(``ddp`` insertion on a single-worker baseline, ``straggler``) declare
empty targets and bound at exactly 1.0x — which is how
``hillclimb --search-whatif`` knows to skip them and says so.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.optimize import (Optimization, OptimizationError, Scenario,
                                 default_candidates)

from .critical_path import extract_critical_path

# Bounds at or below this are "no headroom": greedy search skips them.
NO_HEADROOM = 1.0 + 1e-9


@dataclasses.dataclass(frozen=True)
class _Headroom(Optimization):
    """Internal wrapper: evaluate ``inner``'s idealized best case."""

    inner: Optimization

    def build(self, s: Scenario, tf) -> None:
        if not self.inner.headroom(s, tf):
            raise OptimizationError(
                f"{self.inner.name} declares no shrink-targets; its bound "
                f"is unbounded")


@dataclasses.dataclass
class Opportunity:
    """One candidate's headroom assessment."""

    optimization: Optimization
    bound: float                     # upper-bound speedup; inf == unbounded
    cp_share: Optional[float] = None  # fraction of baseline critical path
    realized: Optional[float] = None  # depth-1 realized speedup
    error: str = ""                  # why realization failed, if it did
    # the realized depth-1 Prediction itself (realize=True only) — drivers
    # seed greedy_search's first round with it instead of re-simulating
    prediction: Optional[object] = \
        dataclasses.field(default=None, repr=False, compare=False)

    @property
    def unbounded(self) -> bool:
        return math.isinf(self.bound)

    @property
    def skipped(self) -> bool:
        """No headroom: the bound proves the candidate cannot improve
        this scenario."""
        return self.bound <= NO_HEADROOM


def opportunity_bound(scenario: Scenario, opt: Optimization) -> float:
    """Upper-bound ``opt``'s speedup on ``scenario`` (see module
    docstring).  ``math.inf`` when the optimization restructures the graph
    and no shrink-bound exists."""
    try:
        pred = scenario.predict(_Headroom(opt))
    except OptimizationError:
        return math.inf
    # monotonicity guarantees >= 1; the max() only absorbs float fuzz
    return max(1.0, pred.speedup)


def rank_opportunities(scenario: Scenario,
                       candidates: Optional[Sequence[Optimization]] = None,
                       *, realize: bool = False,
                       baseline_cluster=None) -> List[Opportunity]:
    """Rank ``candidates`` (default: every default-constructible registered
    optimization) by their speedup upper bound, best headroom first.

    With ``realize=True`` each candidate is additionally evaluated for
    real, so reports can print bound vs realized side by side; the
    :class:`Opportunity` keeps the depth-1 :class:`Prediction` so drivers
    can seed ``greedy_search(round1=...)`` with it instead of
    re-simulating the whole candidate set.  Candidates that do not apply
    to the scenario record the failure instead of a number.

    ``baseline_cluster`` optionally passes the
    :class:`~repro.core.cluster.ClusterGraph` of an already-evaluated
    noop prediction (diagnose/hillclimb have one in hand) so cluster
    scenarios do not rebuild and re-simulate the baseline a second time
    just for the cp-share attribution.
    """
    cands = list(candidates) if candidates is not None \
        else default_candidates(scenario)
    # attribute cp-share against the scenario's *real* baseline route: on
    # cluster/trace scenarios the makespan the bounds are computed against
    # lives on the evaluated cluster graph (stragglers, per-worker traced
    # speeds), not on worker 0's standalone timeline.  Target predicates
    # are written against single-worker thread names (``on_device`` checks
    # ``thread == "device"``), so cluster tasks are matched through a
    # localized read-only view (uid preserved).
    if scenario.is_cluster:
        from repro.core.task import split_worker_thread
        from .critical_path import cluster_critical_path
        cg = baseline_cluster
        if cg is None:
            _, _, cg = scenario.evaluate("noop")
        cp = cluster_critical_path(cg)
        view = []
        for t in cg.graph.tasks():
            lt = dataclasses.replace(t)
            lt.thread = split_worker_thread(t.thread)[1]
            view.append(lt)
    else:
        cp = extract_critical_path(scenario.graph)
        view = scenario.graph.tasks()
    out: List[Opportunity] = []
    for cand in cands:
        bound = opportunity_bound(scenario, cand)
        targets = cand.headroom_targets(scenario)
        share: Optional[float] = None
        if targets is not None:
            share = cp.targeted_share(t.uid for t in view if targets(t))
        opp = Opportunity(optimization=cand, bound=bound, cp_share=share)
        if realize:
            try:
                opp.prediction = scenario.predict(cand)
                opp.realized = opp.prediction.speedup
            except Exception as e:   # candidate not applicable here
                opp.error = f"{type(e).__name__}: {e}"
        out.append(opp)
    out.sort(key=lambda o: (-o.bound, o.optimization.spec()))
    return out


def format_opportunity_table(opps: Sequence[Opportunity], *,
                             title: str = "opportunity ranking") -> str:
    """The bound-vs-realized table ``hillclimb --search-whatif`` and
    ``diagnose`` print."""
    lines = [f"== {title}: Amdahl bounds through the simulator ==",
             f"{'candidate':28s} {'bound':>10s} {'cp-share':>9s} "
             f"{'realized':>9s}  note"]
    for o in opps:
        spec = o.optimization.spec()
        name = spec if len(spec) <= 28 else spec[:25] + "..."
        bound = "unbounded" if o.unbounded else f"{o.bound:.2f}x"
        share = "-" if o.cp_share is None else f"{o.cp_share * 100:.0f}%"
        if o.realized is not None:
            realized = f"{o.realized:.2f}x"
        else:
            realized = "-"
        if o.error:
            note = f"not applicable ({o.error.split(':')[0]})"
        elif o.unbounded:
            note = "restructures the graph; no shrink-bound"
        elif o.skipped:
            note = "skipped: no headroom on this scenario"
        else:
            note = ""
        lines.append(f"{name:28s} {bound:>10s} {share:>9s} {realized:>9s}"
                     f"  {note}".rstrip())
    return "\n".join(lines)


def searchable_candidates(opps: Sequence[Opportunity]
                          ) -> List[Optimization]:
    """Candidates worth handing to greedy search, highest headroom first
    (unbounded ones lead — the ranking cannot rule them out)."""
    return [o.optimization for o in opps if not o.skipped]
