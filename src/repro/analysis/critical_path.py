"""Critical-path extraction: *why* is the makespan what it is.

A simulated makespan is one number; practitioners act on the chain of
tasks that produced it (dPRO's critical-path diagnosis, Skyline's
interactive breakdowns).  :func:`simulate` optionally records, per task,
the *binding predecessor* — the task whose completion set its effective
start (the lane predecessor when the thread was the constraint, the
last-finishing dependency otherwise).  Walking that chain backwards from
the makespan-defining task yields the critical path in O(path length) on
top of the O(E log V) simulation, for single-worker graphs and global
:class:`~repro.core.cluster.ClusterGraph`\\ s alike.

The chain is gap-free by construction: each segment starts exactly when
its binder completes, so the segment ``duration + gap`` values accumulate
to the makespan to float precision — the invariant the test suite and the
golden file anchor on.  Segments are attributed into **compute / comm /
host / offload** by task kind, with ``gap`` time (Daydream §4.2.1 untraced
runtime — host tails, trace start skews) reported as **idle**, and split
per worker on cluster graphs.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

from repro.core.graph import DependencyGraph
from repro.core.simulate import ScheduleFn, SimResult, simulate
from repro.core.task import TaskKind, split_worker_thread

# TaskKind -> critical-path attribution category.  Durations land in these
# buckets; gap time (untraced runtime / start skew) is always "idle".
KIND_CATEGORY = {
    TaskKind.COMPUTE: "compute",
    TaskKind.MEMORY: "compute",
    TaskKind.COLLECTIVE: "comm",
    TaskKind.COMM: "comm",
    TaskKind.HOST: "host",
    TaskKind.DATA: "host",
    TaskKind.SYNC: "host",
    TaskKind.OFFLOAD: "offload",
}

CATEGORIES = ("compute", "comm", "host", "offload", "idle")


@dataclasses.dataclass(frozen=True)
class PathSegment:
    """One task on the makespan-defining chain."""

    uid: int
    name: str
    kind: str                  # TaskKind value string
    thread: str                # global thread name (worker-namespaced)
    worker: Optional[int]      # None for single-worker graphs / barriers
    start: float
    duration: float
    gap: float                 # trailing untraced time, attributed as idle
    category: str              # compute | comm | host | offload

    @property
    def end(self) -> float:
        return self.start + self.duration + self.gap


@dataclasses.dataclass
class CriticalPath:
    """The makespan-defining chain plus its attributions.

    ``segments`` run origin (t=0) to makespan in time order; each segment
    starts exactly when its predecessor on the chain completes.
    """

    segments: List[PathSegment]
    makespan: float

    def breakdown(self) -> Dict[str, float]:
        """Seconds per category (plus ``idle`` = summed gaps); the values
        sum to the makespan to float precision."""
        out = {c: 0.0 for c in CATEGORIES}
        for seg in self.segments:
            out[seg.category] += seg.duration
            out["idle"] += seg.gap
        return out

    def fractions(self) -> Dict[str, float]:
        """Category share of the makespan (0..1; zeros kept so reports and
        goldens are shape-stable)."""
        total = self.makespan or 1.0
        return {c: v / total for c, v in self.breakdown().items()}

    def per_worker(self) -> Dict[Optional[int], float]:
        """Seconds of the path spent on each worker's resources
        (duration + gap; ``None`` collects worker-less lanes such as
        cluster barriers)."""
        out: Dict[Optional[int], float] = collections.defaultdict(float)
        for seg in self.segments:
            out[seg.worker] += seg.duration + seg.gap
        return dict(out)

    def per_class(self, classes) -> Dict[Optional[int], float]:
        """:meth:`per_worker` folded over symmetry classes.

        ``classes`` is the ``WorkerClass`` list of a folded cluster graph
        (``FoldedClusterGraph.classes``): on a folded graph the worker
        index of each segment is a *class* index, and this maps it back to
        the class representative's real worker id — so attributions stay
        comparable with a materialized run's :meth:`per_worker` without
        expanding all members.  ``None`` (cluster barriers) passes
        through.  Expand a class entry to its members on demand via
        ``classes[i].members``: every member shares the representative's
        on-path time by symmetry.
        """
        out: Dict[Optional[int], float] = collections.defaultdict(float)
        for ci, secs in self.per_worker().items():
            out[classes[ci].representative if ci is not None
                else None] += secs
        return dict(out)

    def targeted_share(self, uids) -> float:
        """Fraction of the makespan spent in segments whose uid is in
        ``uids`` — the critical-path attribution signal opportunity
        ranking reports next to its Amdahl bounds."""
        if not self.makespan:
            return 0.0
        uids = set(uids)
        return sum(seg.duration for seg in self.segments
                   if seg.uid in uids) / self.makespan

    # ------------------------------------------------------------- report
    def format(self, *, top: int = 8, unit: float = 1e3,
               unit_name: str = "ms") -> str:
        """Human-readable report: attribution line, per-worker split, and
        the ``top`` longest segments."""
        frac = self.fractions()
        lines = [f"== critical path: {self.makespan * unit:.3f} {unit_name} "
                 f"over {len(self.segments)} task(s) =="]
        lines.append("  " + "  ".join(
            f"{c} {frac[c] * 100:5.1f}%" for c in CATEGORIES
            if frac[c] > 0 or c in ("compute", "comm")))
        pw = self.per_worker()
        if len(pw) > 1 or (pw and next(iter(pw)) is not None):
            lines.append("  on-path time per worker: " + "  ".join(
                f"{'w%d' % w if w is not None else 'sync'}:"
                f"{pw[w] * unit:.3f}"
                for w in sorted(pw, key=lambda x: (x is None, x))))
        longest = sorted(self.segments, key=lambda s: -(s.duration + s.gap))
        for seg in longest[:top]:
            lines.append(
                f"  {(seg.duration + seg.gap) * unit:9.3f} {unit_name}  "
                f"{seg.category:8s} {seg.thread:18s} {seg.name}")
        return "\n".join(lines)


def _worker_of(thread: str) -> Optional[int]:
    return split_worker_thread(thread)[0]


def extract_critical_path(graph: DependencyGraph,
                          result: Optional[SimResult] = None,
                          schedule: Optional[ScheduleFn] = None
                          ) -> CriticalPath:
    """Extract the makespan-defining chain of ``graph``.

    ``result`` must carry binding predecessors
    (``simulate(record_binding=True)``); when it is missing or was produced
    without recording, the graph is re-simulated with recording on (same
    engine, bit-identical timeline).  The walk itself is O(path length);
    end to end the extraction is O(E log V) — dominated by the simulation.
    """
    provided = result
    if result is None or result.binding is None:
        result = simulate(graph, schedule, record_binding=True)
        if provided is not None and \
                abs(provided.makespan - result.makespan) > \
                1e-9 * max(abs(result.makespan), 1e-30):
            # the caller's result describes durations the graph no longer
            # has (a sweep retuned the shared build in place): re-deriving
            # would silently return a *different point's* path
            raise RuntimeError(
                f"provided result (makespan {provided.makespan}) no longer "
                f"matches the graph (makespan {result.makespan}): it was "
                f"retuned after the result was produced — re-simulate "
                f"before extracting the critical path")
    if not result.start:
        return CriticalPath(segments=[], makespan=0.0)
    binding = result.binding
    finish = result.finish

    def done(uid: int) -> float:
        return finish[uid] + graph.get(uid).gap

    end_uid = max(finish, key=lambda u: (done(u), -u))
    chain: List[int] = []
    seen = set()
    uid: Optional[int] = end_uid
    while uid is not None:
        if uid in seen:          # defensive: a cycle here is an engine bug
            raise RuntimeError("binding chain loops — simulator invariant "
                               "violated")
        seen.add(uid)
        chain.append(uid)
        uid = binding.get(uid)
    chain.reverse()
    segments = []
    t_acc = 0.0
    for u in chain:
        t = graph.get(u)
        # contiguity check doubles as a staleness guard: with a fresh
        # result every chain task starts exactly when its binder completes
        # (same float ops), so a drift beyond noise means the graph's
        # durations/gaps were retuned after ``result`` was simulated
        if abs(result.start[u] - t_acc) > \
                1e-12 * (abs(t_acc) + abs(result.start[u])) + 1e-18:
            raise RuntimeError(
                f"binding chain is discontiguous at task {t.name!r} "
                f"(start {result.start[u]} vs chain time {t_acc}): the "
                f"graph was retuned after this result was produced — "
                f"re-simulate before extracting the critical path")
        t_acc = t_acc + t.duration
        t_acc = t_acc + t.gap
        segments.append(PathSegment(
            uid=u, name=t.name, kind=t.kind.value, thread=t.thread,
            worker=_worker_of(t.thread), start=result.start[u],
            duration=t.duration, gap=t.gap,
            category=KIND_CATEGORY.get(t.kind, "compute")))
    return CriticalPath(segments=segments, makespan=done(end_uid))


def cluster_critical_path(cluster_graph, result=None) -> CriticalPath:
    """:func:`extract_critical_path` over a
    :class:`~repro.core.cluster.ClusterGraph`.

    ``result`` is the :class:`~repro.core.cluster.ClusterResult` of
    ``cluster_graph.simulate(record_binding=True)``; without one (or
    without recording) the global graph is re-simulated with recording.
    Segments carry worker indices, so :meth:`CriticalPath.per_worker`
    answers "whose resources is the makespan made of".
    """
    res = getattr(result, "global_result", result)
    return extract_critical_path(cluster_graph.graph, res,
                                 cluster_graph.schedule)
