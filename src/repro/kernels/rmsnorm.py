"""Fused RMSNorm Pallas TPU kernel — the Reconstructing-BatchNorm analogue.

Paper §6.4 splits/fuses normalization with neighbouring kernels to halve the
normalized tensor's HBM reads.  The LM-era equivalent is a fused RMSNorm:
one pass reads x, computes the f32 mean-square across the feature dim, and
writes the scaled output — instead of the unfused square / mean / rsqrt /
mul / mul chain (5 reads + 4 writes -> 1 read + 1 write).

Layout: x (rows, D) with D a multiple of 128 (ops wrapper pads); one
row-block per grid step, weight broadcast to every block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d_real: int):
    x = x_ref[...].astype(jnp.float32)                 # (blk, D)
    D = x.shape[-1]
    if d_real != D:                                    # padded tail is zero
        denom = float(d_real)
    else:
        denom = float(D)
    ms = jnp.sum(x * x, axis=-1, keepdims=True) / denom
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
               d_real: int = 0, interpret: bool = True) -> jax.Array:
    rows, D = x.shape
    blk = min(BLOCK_ROWS, rows)
    grid = (rows // blk,)
    kern = functools.partial(_rmsnorm_kernel, eps=eps, d_real=d_real or D)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((blk, D), lambda i: (i, 0)),
                  pl.BlockSpec((1, D), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((blk, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x, w.reshape(1, D))
