"""DGC gradient-sparsification Pallas TPU kernel (threshold selection stage).

Deep Gradient Compression (paper §5.2 / Algorithm 12) transmits only the
largest-magnitude gradient entries.  Exact global top-k is a poor fit for the
VPU; the TPU-native formulation (as in production DGC implementations) is
*threshold sparsification*: estimate the k-th magnitude from a sample on the
host/XLA side, then run one vectorized pass that zeroes everything below the
threshold and counts survivors.  This kernel is that pass; ``ops.dgc_mask``
wraps it, and ``ref.dgc_topk_ref`` is the exact top-k oracle the tests
compare against (using the oracle's own k-th value as the threshold).

Layout: (rows, LANE) f32 blocks like fused_adam.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_ROWS = 8


def _dgc_kernel(g_ref, thr_ref, o_ref, cnt_ref):
    g = g_ref[...].astype(jnp.float32)
    thr = thr_ref[0]
    keep = jnp.abs(g) >= thr
    o_ref[...] = jnp.where(keep, g, 0.0).astype(o_ref.dtype)
    cnt_ref[...] = jnp.sum(keep.astype(jnp.int32), axis=-1, keepdims=True)


def dgc_threshold_2d(g: jax.Array, thr: jax.Array, *,
                     interpret: bool = True):
    """g: (rows, LANE) f32; thr: (1,) f32 -> (sparse g, per-row keep counts)."""
    rows = g.shape[0]
    blk = min(BLOCK_ROWS, rows)
    grid = (rows // blk,)
    return pl.pallas_call(
        _dgc_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((blk, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((blk, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), g.dtype),
                   jax.ShapeDtypeStruct((rows, 1), jnp.int32)],
        interpret=interpret,
    )(g, thr)
