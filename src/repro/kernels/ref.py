"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KH, S, D) — naive full-score attention."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    G = H // KH
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def fused_adam_ref(p, g, m, v, *, lr, b1, b2, eps, wd, c1, c2):
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g
    v_new = b2 * v + (1 - b2) * g * g
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * p
    return p - lr * step, m_new, v_new


def rmsnorm_ref(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def dgc_topk_ref(g, ratio: float):
    """Exact top-|k|: returns (sparse gradient, k, threshold)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(round(ratio * flat.size)))
    vals = jnp.sort(jnp.abs(flat))[::-1]
    thr = vals[k - 1]
    sparse = jnp.where(jnp.abs(flat) >= thr, flat, 0.0)
    return sparse.reshape(g.shape).astype(g.dtype), k, thr
