"""Flash attention Pallas TPU kernel (blockwise causal attention).

TPU adaptation of the paper-era GPU flash algorithm (DESIGN.md §2): instead of
warp-level softmax reductions, the kernel tiles (q_block x k_block) score
tiles through VMEM with MXU-aligned 128x128 blocks; running max / denominator
/ accumulator live in VMEM scratch across the innermost k-grid dimension.
Scores never touch HBM — this removes the O(S^2) HBM traffic that makes the
pure-XLA chunked attention memory-bound (EXPERIMENTS.md §Perf).

Layouts: q (B, H, S, D), k/v (B, KH, S, D); GQA handled by mapping each q
head h to kv head h // (H // KH) in the BlockSpec index maps.  D padded to a
multiple of 128 by the ops wrapper.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    run = True
    if causal:
        # whole k block strictly after the last q row -> nothing to do
        run = k_start <= q_start + block_q - 1

    @pl.when(jnp.asarray(run))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    sm_scale: float = 0.0,
                    kv_len: int = 0,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KH, S, D) -> (B, H, S, D).

    D must be a multiple of 128 and S a multiple of the block sizes (the ops
    wrapper pads; ``sm_scale``/``kv_len`` carry the pre-padding softmax scale
    and valid key count).  ``interpret=True`` executes the kernel body in
    Python on CPU (the validation mode here); on a real TPU pass False.
    """
    B, H, S, D = q.shape
    KH = k.shape[1]
    G = H // KH
    scale = sm_scale or 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = S // block_q
    nk = S // block_k
    grid = (B, H, nq, nk)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_len=kv_len or S)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
