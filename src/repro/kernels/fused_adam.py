"""FusedAdam Pallas TPU kernel (paper §6.3).

One VMEM-tiled pass over contiguous (param, grad, m, v) vectors producing the
updated triple — the TPU analogue of Apex FusedAdam: the paper's win was
eliminating thousands of CUDA launches; the TPU win is eliminating per-op
dispatch/fusion overhead and re-reading the same vectors across the ~10
element-wise stages of an unfused Adam chain (read p,g,m,v once, write p,m,v
once: 7 vector transfers instead of ~20).

Layout: the ops wrapper flattens/pads to (rows, LANE) with LANE=1024 (8x128
VPU tiles); the kernel runs one row-block per grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_ROWS = 8


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, c1_ref, c2_ref,
                 po_ref, mo_ref, vo_ref, *, b1: float, b2: float,
                 eps: float, wd: float):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    lr = lr_ref[0]
    c1 = c1_ref[0]
    c2 = c2_ref[0]
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * p
    po_ref[...] = (p - lr * step).astype(po_ref.dtype)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def fused_adam_2d(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                  lr: jax.Array, c1: jax.Array, c2: jax.Array, *,
                  b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                  wd: float = 0.1, interpret: bool = True):
    """All arrays (rows, LANE) f32; lr/c1/c2 shape-(1,) f32 scalars."""
    rows = p.shape[0]
    blk = min(BLOCK_ROWS, rows)
    grid = (rows // blk,)
    kern = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    vec = pl.BlockSpec((blk, LANE), lambda i: (i, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((rows, LANE), jnp.float32)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scal, scal, scal],
        out_specs=[vec, vec, vec],
        out_shape=[out, out, out],
        interpret=interpret,
    )(p, g, m, v, lr, c1, c2)
