"""Jit'd public wrappers for the Pallas kernels: padding, reshaping, dtype
management.  ``interpret`` defaults to True (this container validates kernels
via the Pallas interpreter); a TPU deployment flips ``set_interpret(False)``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import fused_adam as _ad
from . import rmsnorm as _rn
from . import dgc_topk as _dg

_INTERPRET = True


def set_interpret(flag: bool) -> None:
    global _INTERPRET
    _INTERPRET = flag


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad), n


# ------------------------------------------------------------------ flash
@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KH, S, D).  Pads D->128k, S->block mult."""
    B, H, S, D = q.shape
    import math
    qp, _ = _pad_to(q, 3, 128)
    kp, _ = _pad_to(k, 3, 128)
    vp, _ = _pad_to(v, 3, 128)
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    sm = max(bq, bk)
    qp, _ = _pad_to(qp, 2, sm)
    kp, _ = _pad_to(kp, 2, sm)
    vp, _ = _pad_to(vp, 2, sm)
    # padded key positions are masked via kv_len; scale uses the real D
    out = _fa.flash_attention(qp, kp, vp, causal=causal, block_q=bq,
                              block_k=bk, sm_scale=1.0 / math.sqrt(D),
                              kv_len=S, interpret=_INTERPRET)
    return out[:, :, :S, :D]


# ------------------------------------------------------------- fused adam
@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd"))
def fused_adam(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array, *,
               lr, b1: float, b2: float, eps: float, wd: float, c1, c2):
    """Flat f32 vectors (N,) -> updated (p, m, v)."""
    N = p.shape[0]
    lane = _ad.LANE

    def to2d(x):
        xp, _ = _pad_to(x.astype(jnp.float32), 0, lane)
        return xp.reshape(-1, lane)

    p2, g2, m2, v2 = map(to2d, (p, g, m, v))
    rows = p2.shape[0]
    blk = min(_ad.BLOCK_ROWS, rows)
    if rows % blk:
        extra = blk - rows % blk
        z = jnp.zeros((extra, lane), jnp.float32)
        p2, g2, m2, v2 = (jnp.concatenate([a, z]) for a in (p2, g2, m2, v2))
    po, mo, vo = _ad.fused_adam_2d(
        p2, g2, m2, v2,
        jnp.asarray(lr, jnp.float32).reshape(1),
        jnp.asarray(c1, jnp.float32).reshape(1),
        jnp.asarray(c2, jnp.float32).reshape(1),
        b1=b1, b2=b2, eps=eps, wd=wd, interpret=_INTERPRET)
    return (po.reshape(-1)[:N], mo.reshape(-1)[:N], vo.reshape(-1)[:N])


# ---------------------------------------------------------------- rmsnorm
@jax.jit
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: (..., D), w: (D,) -> fused RMSNorm over the last dim."""
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    x2p, _ = _pad_to(x2, 1, 128)
    wp, _ = _pad_to(w, 0, 128)
    rows = x2p.shape[0]
    blk = min(_rn.BLOCK_ROWS, rows)
    padr = 0
    if rows % blk:
        padr = blk - rows % blk
        x2p = jnp.concatenate(
            [x2p, jnp.zeros((padr, x2p.shape[1]), x2p.dtype)])
    out = _rn.rmsnorm_2d(x2p, wp, eps=eps, d_real=D, interpret=_INTERPRET)
    if padr:
        out = out[:-padr]
    return out[:, :D].reshape(shape)


# --------------------------------------------------------------- dgc mask
@jax.jit
def dgc_mask(g: jax.Array, threshold: jax.Array):
    """Zero entries with |g| < threshold.  Returns (sparse g, kept count)."""
    shape = g.shape
    flat = g.reshape(-1).astype(jnp.float32)
    N = flat.shape[0]
    lane = _dg.LANE
    fp, _ = _pad_to(flat, 0, lane)
    g2 = fp.reshape(-1, lane)
    rows = g2.shape[0]
    blk = min(_dg.BLOCK_ROWS, rows)
    padr = 0
    if rows % blk:
        padr = blk - rows % blk
        g2 = jnp.concatenate([g2, jnp.zeros((padr, lane), jnp.float32)])
    out, cnt = _dg.dgc_threshold_2d(
        g2, jnp.asarray(threshold, jnp.float32).reshape(1),
        interpret=_INTERPRET)
    if padr:
        out, cnt = out[:-padr], cnt[:-padr]
    sparse = out.reshape(-1)[:N].reshape(shape).astype(g.dtype)
    # padded zeros never pass |0| >= thr for thr > 0
    return sparse, jnp.sum(cnt)
