"""Training loop: jit-compiled step, sharded state, checkpoints, fault hooks.

Composition of the substrate layers:
  models.make_train_step  (loss + AdamW update, grad-accum aware)
  data.SyntheticLM        (per-host batch slices, prefetch)
  ckpt.CheckpointManager  (atomic, async, elastic re-shard)
  runtime.*               (heartbeat, straggler monitor, retry driver)

Works on a laptop (no mesh), the single-pod mesh, and the multi-pod mesh —
the sharding rules resolve against whatever mesh is active.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import (ModelConfig, init_params, make_train_step)
from repro.models.paramdecl import SpecLeaf, specs_of
from repro.optim import AdamW
from repro.ckpt import CheckpointManager
from repro.runtime import Heartbeat, StragglerMonitor
from repro.sharding import ShardingRules, DEFAULT_RULES


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_async: bool = True
    seed: int = 0
    straggler_threshold: float = 2.5


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 optimizer: Optional[AdamW] = None, mesh=None,
                 rules: ShardingRules = DEFAULT_RULES) -> None:
        self.cfg = cfg
        self.tc = tc
        self.opt = optimizer or AdamW()
        self.mesh = mesh
        self.rules = rules
        self.step_fn = make_train_step(cfg, self.opt)
        self.ckpt = (CheckpointManager(tc.ckpt_dir) if tc.ckpt_dir else None)
        self.straggler = StragglerMonitor(threshold=tc.straggler_threshold)
        self.metrics_log: list = []
        self._jitted = None

    # ------------------------------------------------------------- state
    def init_state(self) -> Dict[str, Any]:
        params = init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        return {"params": params, "opt": self.opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def state_shardings(self):
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec_state = {"params": init_params(self.cfg, None), "opt": None,
                      "step": SpecLeaf((), jnp.dtype(jnp.int32), ())}
        spec_state["opt"] = self.opt.init(spec_state["params"])
        spec_tree = specs_of(spec_state, self.rules)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def jitted_step(self):
        if self._jitted is None:
            sh = self.state_shardings()
            self._jitted = jax.jit(self.step_fn, in_shardings=(sh, None),
                                   out_shardings=(sh, None),
                                   donate_argnums=(0,))
        return self._jitted

    # --------------------------------------------------------------- loop
    def restore_or_init(self) -> Dict[str, Any]:
        if self.ckpt and self.ckpt.latest_step() is not None:
            like = jax.eval_shape(self.init_state)
            state, _ = self.ckpt.restore_latest(
                like, mesh=self.mesh, shardings=self.state_shardings())
            return state
        return self.init_state()

    def fit(self, batches: Iterator[Dict[str, np.ndarray]],
            steps: Optional[int] = None,
            hooks: Optional[Callable[[int, Dict], None]] = None
            ) -> Dict[str, Any]:
        steps = steps or self.tc.steps
        state = self.restore_or_init()
        start = int(jax.device_get(state["step"]))
        step_fn = self.jitted_step()
        it = iter(batches)
        for i in range(start, steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            dt = time.time() - t0
            self.straggler.record(i, dt)
            metrics.update(step=i, step_time_s=dt)
            self.metrics_log.append(metrics)
            if hooks:
                hooks(i, metrics)
            if self.tc.log_every and (i % self.tc.log_every == 0):
                print(f"step {i:6d} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics.get('grad_norm', 0):.3f} "
                      f"dt={dt*1e3:.1f}ms", flush=True)
            if self.ckpt and ((i + 1) % self.tc.ckpt_every == 0
                              or i + 1 == steps):
                if self.tc.ckpt_async:
                    self.ckpt.save_async(i, state)
                else:
                    self.ckpt.save(i, state)
        if self.ckpt:
            self.ckpt.wait()
        return state
