"""Parameter declaration: one init function, three products.

Model init code declares every parameter through the constructors below.  The
same code path then yields, depending on mode:

  * real mode  (``key`` is a PRNG key)   -> initialized, sharding-constrained arrays
  * spec mode  (``key`` is ``None``)     -> :class:`SpecLeaf` placeholders carrying
                                            (shape, dtype, logical axes)

``specs_of``/``shapes_of`` turn a spec-mode tree into the PartitionSpec tree /
ShapeDtypeStruct tree the dry-run needs — so ``in_shardings`` can never drift
from what init actually builds (single source of truth).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import ShardingRules, DEFAULT_RULES, shard


@dataclasses.dataclass
class SpecLeaf:
    """Abstract parameter: shape + dtype + logical sharding axes."""

    shape: Tuple[int, ...]
    dtype: Any
    logical: Tuple[Optional[str], ...]

    def __repr__(self) -> str:
        return f"SpecLeaf{self.shape}:{jnp.dtype(self.dtype).name}:{self.logical}"


jax.tree_util.register_pytree_node(
    SpecLeaf,
    lambda l: ((), (l.shape, l.dtype, l.logical)),
    lambda aux, _: SpecLeaf(*aux),
)


def is_spec_mode(key) -> bool:
    return key is None


def split_keys(key, n: int) -> List:
    """PRNG split that degrades to Nones in spec mode."""
    if key is None:
        return [None] * n
    return list(jax.random.split(key, n))


def _finish(key, shape, dtype, logical, sample: Callable[[], jax.Array]):
    if key is None:
        return SpecLeaf(tuple(shape), jnp.dtype(dtype), tuple(logical))
    return shard(sample(), *logical)


def normal_param(key, shape: Sequence[int], dtype, *logical: Optional[str],
                 scale: Optional[float] = None):
    """Fan-in scaled gaussian (the default dense/embedding initializer)."""
    logical = _pad_logical(logical, shape)

    def sample():
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, tuple(shape), jnp.float32) * s).astype(dtype)

    return _finish(key, shape, dtype, logical, sample)


def zeros_param(key, shape: Sequence[int], dtype, *logical: Optional[str]):
    logical = _pad_logical(logical, shape)
    return _finish(key, shape, dtype, logical,
                   lambda: jnp.zeros(tuple(shape), dtype))


def ones_param(key, shape: Sequence[int], dtype, *logical: Optional[str]):
    logical = _pad_logical(logical, shape)
    return _finish(key, shape, dtype, logical,
                   lambda: jnp.ones(tuple(shape), dtype))


def uniform_param(key, shape: Sequence[int], dtype, *logical: Optional[str],
                  lo: float = -1.0, hi: float = 1.0):
    logical = _pad_logical(logical, shape)
    return _finish(key, shape, dtype, logical,
                   lambda: jax.random.uniform(
                       key, tuple(shape), jnp.float32, lo, hi).astype(dtype))


def _pad_logical(logical: Sequence[Optional[str]], shape: Sequence[int]
                 ) -> Tuple[Optional[str], ...]:
    lg = tuple(logical)
    if len(lg) < len(shape):
        lg = (None,) * (len(shape) - len(lg)) + lg
    return lg


# ------------------------------------------------------------- layer stacks
def stacked_init(n_layers: int, layer_init: Callable[[Any], Any], key):
    """Initialize ``n_layers`` identical layers stacked on a leading axis.

    Real mode: ``vmap`` the per-layer init over split keys.  Spec mode: run the
    init once and prepend the layer dimension (replicated) to every leaf.
    """
    if key is None:
        one = layer_init(None)
        return jax.tree.map(
            lambda l: SpecLeaf((n_layers,) + l.shape, l.dtype,
                               (None,) + l.logical, ),
            one, is_leaf=lambda x: isinstance(x, SpecLeaf))
    keys = jnp.stack(split_keys(key, n_layers))
    return jax.vmap(layer_init)(keys)


# ------------------------------------------------------------- tree products
def specs_of(tree, rules: ShardingRules = DEFAULT_RULES):
    """SpecLeaf tree -> PartitionSpec tree (resolved on the active mesh)."""
    def leaf(l):
        if isinstance(l, SpecLeaf):
            return rules.spec(*l.logical, dim_sizes=list(l.shape))
        raise TypeError(f"specs_of expects SpecLeaf leaves, got {type(l)}")
    return jax.tree.map(leaf, tree, is_leaf=lambda x: isinstance(x, SpecLeaf))


def shapes_of(tree):
    """SpecLeaf tree -> ShapeDtypeStruct tree (for .lower with no allocation)."""
    def leaf(l):
        if isinstance(l, SpecLeaf):
            return jax.ShapeDtypeStruct(l.shape, l.dtype)
        raise TypeError(f"shapes_of expects SpecLeaf leaves, got {type(l)}")
    return jax.tree.map(leaf, tree, is_leaf=lambda x: isinstance(x, SpecLeaf))


def sharded_shapes_of(tree, mesh, rules: ShardingRules = DEFAULT_RULES):
    """SpecLeaf tree -> ShapeDtypeStruct tree with NamedSharding attached."""
    from jax.sharding import NamedSharding

    def leaf(l):
        spec = rules.spec(*l.logical, dim_sizes=list(l.shape))
        return jax.ShapeDtypeStruct(l.shape, l.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(leaf, tree, is_leaf=lambda x: isinstance(x, SpecLeaf))


def count_params(tree) -> int:
    """Total parameter count for a SpecLeaf tree or a real param tree."""
    total = 0
    for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, SpecLeaf)):
        if isinstance(l, SpecLeaf):
            n = 1
            for d in l.shape:
                n *= d
        else:
            n = l.size
        total += n
    return total
