"""RG-LRU recurrent block (Griffin / RecurrentGemma) + local-attention pairing.

Train-time recurrence uses ``jax.lax.associative_scan`` over the sequence — the
TPU-native parallel-scan analogue of Griffin's custom kernel (log-depth, fully
vectorized across channels).  Decode carries (conv window, h state) per layer,
so the ``long_500k`` decode cell is O(window) in memory, not O(S).

Block layout (RecurrentGemma):
  residual -> norm -> [x-branch: linear -> causal conv4 -> RG-LRU]
                      [gate-branch: linear -> gelu]
              merge (x * gate) -> out-proj -> +residual
RG-LRU:  r_t = sigmoid(W_a x_t + b_a); i_t = sigmoid(W_x x_t + b_x)
         log a_t = -c * softplus(Lambda) * r_t        (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, use_weight
from .paramdecl import normal_param, zeros_param, split_keys

Params = Dict[str, Any]

CONV_K = 4
LRU_C = 8.0


def rglru_init(key, d: int, d_rnn: int, dtype) -> Params:
    k1, k2, k3, k4, k5, k6 = split_keys(key, 6)
    return {
        "w_in": normal_param(k1, (d, d_rnn), dtype, "fsdp", "ff_mega"),
        "w_gate": normal_param(k2, (d, d_rnn), dtype, "fsdp", "ff_mega"),
        "conv": normal_param(k3, (CONV_K, d_rnn), dtype, None, "heads",
                             scale=0.5),
        "w_a": normal_param(k4, (d_rnn, d_rnn), dtype, "heads", "out_fsdp"),
        "b_a": zeros_param(k4, (d_rnn,), jnp.float32, None),
        "w_i": normal_param(k5, (d_rnn, d_rnn), dtype, "heads", "out_fsdp"),
        "b_i": zeros_param(k5, (d_rnn,), jnp.float32, None),
        "lam": zeros_param(k5, (d_rnn,), jnp.float32, None),
        "w_out": normal_param(k6, (d_rnn, d), dtype, "heads", "out_fsdp"),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    out = x * kernel[-1]
    for i in range(1, CONV_K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        out = out + shifted * kernel[CONV_K - 1 - i]
    return out


def _gates(p: Params, xb: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(log_a, beta*i*x) from the conv'd x-branch.  Shapes (B,S,D)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xb,
                   use_weight(p["w_a"], "heads", None)).astype(jnp.float32)
        + p["b_a"])
    i = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xb,
                   use_weight(p["w_i"], "heads", None)).astype(jnp.float32)
        + p["b_i"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r          # (B,S,D) f32
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    b = beta * i * xb.astype(jnp.float32)
    return log_a, b


def rglru_forward(p: Params, x: jax.Array, *, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) with parallel-scan recurrence."""
    with jax.named_scope("rglru"):
        gate = jax.nn.gelu(jnp.einsum(
            "bsd,de->bse", x, use_weight(p["w_gate"], None, "heads")))
        xb_pre = jnp.einsum("bsd,de->bse", x,
                            use_weight(p["w_in"], None, "heads"))
        xb_pre = shard(xb_pre, "batch", None, "heads")
        xb = _causal_conv(xb_pre, p["conv"])
        log_a, b = _gates(p, xb)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, jnp.exp(a2) * b1 + b2

        _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
        y = (h.astype(x.dtype) * gate)
        y = shard(y, "batch", None, "heads")
        out = jnp.einsum("bse,ed->bsd", y,
                         use_weight(p["w_out"], "heads", None))
        out = shard(out, "batch", None, None)
        if not return_state:
            return out
        S = x.shape[1]
        tail = jnp.pad(xb_pre, ((0, 0), (CONV_K - 1, 0), (0, 0)))[
            :, S:S + CONV_K - 1, :]
        return out, {"conv": tail, "h": h[:, -1]}


def rglru_decode(p: Params, x: jax.Array, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    """One-token step.  cache: {"conv": (B, K-1, d_rnn), "h": (B, d_rnn)}."""
    with jax.named_scope("rglru"):
        gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"]))[:, 0]
        xb = jnp.einsum("bsd,de->bse", x, p["w_in"])[:, 0]     # (B, d_rnn)
        window = jnp.concatenate([cache["conv"], xb[:, None, :]], axis=1)
        xc = jnp.einsum("bke,ke->be", window, p["conv"].astype(window.dtype))
        log_a, b = _gates(p, xc[:, None, :])
        log_a, b = log_a[:, 0], b[:, 0]
        h = jnp.exp(log_a) * cache["h"] + b                    # f32 state
        y = (h.astype(x.dtype) * gate)
        out = jnp.einsum("be,ed->bd", y, p["w_out"])[:, None, :]
        return out, {"conv": window[:, 1:], "h": h}


def rglru_cache_spec(batch: int, d_rnn: int, dtype) -> Params:
    from .paramdecl import SpecLeaf
    return {
        "conv": SpecLeaf((batch, CONV_K - 1, d_rnn), jnp.dtype(dtype),
                         ("batch", None, "heads")),
        "h": SpecLeaf((batch, d_rnn), jnp.dtype(jnp.float32),
                      ("batch", "heads")),
    }
