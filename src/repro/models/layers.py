"""Shared model building blocks — pure JAX, named-scoped, logically sharded.

Every block is an (init, apply) pair over plain pytrees.  Init goes through
``repro.models.paramdecl`` constructors, so the same code yields real params
(PRNG key) or SpecLeaf placeholders (key=None) — see paramdecl docstring.
``jax.named_scope`` wraps each layer so Daydream's task->layer mapping
(core/layermap.py) is exact.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, use_weight
from .paramdecl import (normal_param, zeros_param, ones_param, split_keys)

Params = Dict[str, Any]

ACTIVATIONS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
               "tanh": jnp.tanh}


# --------------------------------------------------------------- rmsnorm
def rmsnorm_init(key, d: int, dtype) -> Params:
    return {"scale": ones_param(key, (d,), dtype, None)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    with jax.named_scope("norm"):
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(key, d: int, dtype) -> Params:
    return {"scale": ones_param(key, (d,), dtype, None),
            "bias": zeros_param(key, (d,), dtype, None)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    with jax.named_scope("norm"):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d: int, dtype) -> Params:
    return {"table": normal_param(key, (vocab, d), dtype, "vocab_mega", "fsdp",
                                  scale=0.02)}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    with jax.named_scope("embed"):
        table = use_weight(p["table"], "vocab", None)
        out = jnp.take(table, ids, axis=0)
        return shard(out, "batch", None, None)


def unembed_logits(p: Params, x: jax.Array) -> jax.Array:
    """(..., d) @ (vocab, d)^T -> (..., vocab), vocab-sharded."""
    with jax.named_scope("unembed"):
        logits = jnp.einsum("...d,vd->...v", x,
                            use_weight(p["table"], "vocab", None))
        return shard(logits, "batch", None, "vocab")


# -------------------------------------------------------------------- mlp
def mlp_init(key, d: int, d_ff: int, dtype, *, gated: bool = True,
             bias: bool = False) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    p: Params = {
        "w_up": normal_param(k1, (d, d_ff), dtype, "fsdp", "ff_mega"),
        "w_down": normal_param(k2, (d_ff, d), dtype, "ff", "out_fsdp"),
    }
    if gated:
        p["w_gate"] = normal_param(k3, (d, d_ff), dtype, "fsdp", "ff_mega")
    if bias:
        p["b_up"] = zeros_param(k1, (d_ff,), dtype, "ff")
        p["b_down"] = zeros_param(k2, (d,), dtype, None)
    return p


def mlp(p: Params, x: jax.Array, *, activation: str = "silu") -> jax.Array:
    with jax.named_scope("mlp"):
        act = ACTIVATIONS[activation]
        w_up = use_weight(p["w_up"], None, "ff")
        up = jnp.einsum("...d,df->...f", x, w_up)
        if "b_up" in p:
            up = up + p["b_up"]
        if "w_gate" in p:
            gate = act(jnp.einsum("...d,df->...f", x,
                                  use_weight(p["w_gate"], None, "ff")))
            h = gate * up
        else:
            h = act(up)
        h = shard(h, "batch", None, "ff")
        out = jnp.einsum("...f,fd->...d", h,
                         use_weight(p["w_down"], "ff", None))
        if "b_down" in p:
            out = out + p["b_down"]
        return out


# ------------------------------------------------------- chunked CE loss
def softmax_cross_entropy_chunked(embed_params: Params, x: jax.Array,
                                  labels: jax.Array, mask: Optional[jax.Array],
                                  chunk: int = 2048) -> jax.Array:
    """Per-token CE against the unembedding, computed in *sequence* chunks so
    the full (tokens, vocab) logit tensor never materializes — essential for
    the 256k-vocab architectures.

    Chunking runs along the sequence dim with the batch dim kept intact (and
    batch-sharded): chunking across the flattened (B*S) token axis crosses
    batch-shard boundaries and forced GSPMD to all-gather every chunk (§Perf
    iteration 2; was 2x8.6 GB/device of loss-loop all-gathers).  Tables under
    256 MB are replicated at use (one small all-gather per pass) instead of
    keeping the contraction vocab-sharded (one dx all-reduce per chunk).
    """
    with jax.named_scope("loss"):
        from repro.sharding import active_rules, mesh_axis_sizes
        B, S, D = x.shape
        m = (mask.astype(jnp.float32) if mask is not None
             else jnp.ones((B, S), jnp.float32))
        # chunk size targets ~`chunk` tokens *per device*: divide the global
        # batch by its shard factor (a global-B divisor here cost 512 scan
        # trips and a per-trip table gather under the dp layout)
        sizes = mesh_axis_sizes()
        phys = active_rules().physical("batch", dim_size=B)
        axes = (phys,) if isinstance(phys, str) else tuple(phys or ())
        fac = 1
        for a in axes:
            fac *= sizes.get(a, 1)
        b_dev = max(1, B // max(fac, 1))
        cs = max(1, min(max(chunk // b_dev, 1), S))
        nchunk = (S + cs - 1) // cs
        pad = nchunk * cs - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            m = jnp.pad(m, ((0, 0), (0, pad)))
        # (nchunk, B, cs, ...): scan over sequence chunks, batch stays sharded
        xc = x.reshape(B, nchunk, cs, D).transpose(1, 0, 2, 3)
        yc = labels.reshape(B, nchunk, cs).transpose(1, 0, 2)
        mc = m.reshape(B, nchunk, cs).transpose(1, 0, 2)
        table = embed_params["table"]
        small = table.size * 2 <= 256 * 1024 * 1024
        # gather ONCE outside the scan (loop-invariant input: the bwd table
        # gradient then accumulates locally and syncs once, not per chunk)
        tb = use_weight(table, None if small else "vocab", None)

        @jax.checkpoint   # recompute per-chunk logits in bwd: O(chunk*V) temp
        def body(carry, inp):
            xb, yb, mb = inp
            xb = shard(xb, "batch", None, None)
            logits = jnp.einsum("bsd,vd->bsv", xb, tb).astype(jnp.float32)
            if not small:
                logits = shard(logits, "batch", None, "vocab")
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mb
            return carry + jnp.sum(nll), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc, mc))
        denom = jnp.maximum(jnp.sum(m), 1.0)
        return total / denom
