"""ModelConfig + build_model: the public model API for all ten architectures.

``build_model(cfg)`` returns a :class:`Model` whose functions are pure and
jit/pjit-able:

    model.init(key)                      -> params    (key=None -> SpecLeaf tree)
    model.loss(params, batch)            -> scalar loss          (train)
    model.prefill(params, batch)         -> (logits, cache)      (inference)
    model.decode(params, cache, tok, pos)-> (logits, cache)      (one token)
    model.cache_spec(batch, seq)         -> SpecLeaf cache tree

Plus step factories (``make_train_step`` / ``make_serve_step`` /
``make_prefill_step``) and ``input_specs`` which produce the ShapeDtypeStruct
stand-ins + NamedShardings the multi-pod dry-run lowers with (no allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import (ShardingRules, DEFAULT_RULES, shard,
                            set_active_layout)
from .paramdecl import (SpecLeaf, split_keys, stacked_init, specs_of,
                        shapes_of, sharded_shapes_of, count_params as
                        _count_params, normal_param)
from .layers import (embedding_init, embed, rmsnorm_init, rmsnorm,
                     softmax_cross_entropy_chunked, mlp_init, mlp)
from .attention import rope_angles
from . import transformer as T

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    activation: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"
    attn_bias: bool = False
    tie_embeddings: bool = False
    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # --- MLA (DeepSeek-V2)
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 128
    qk_rope: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # --- hybrid (recurrentgemma)
    window: int = 0               # local-attention window (0 = full attention)
    d_rnn: int = 0
    # --- encdec (seamless)
    enc_layers: int = 0
    dec_layers: int = 0
    cross_len: int = 0            # encoder length for decode cache (0 = seq)
    # --- vlm (internvl)
    n_patches: int = 0
    # --- compilation / perf knobs (§Perf hillclimb surface)
    layout: str = "v2"            # train sharding layout: baseline | v2 | dp
    serve_layout: str = "v2"      # decode/prefill layout (weight-stationary TP)
    serve_fsdp: bool = True       # False: replicate weights over data when
                                  # they fit (kills per-token FSDP gathers)
    remat: str = "full"           # none | full | dots | offload
    scan_layers: bool = True
    attn_chunk: int = 1024
    loss_chunk: int = 2048
    grad_accum: int = 1
    # --- applicability flags
    sub_quadratic: bool = False   # may run long_500k
    decode_supported: bool = True

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# family -> (block_init, block_apply, block_decode, block_prefill, cache_spec)
_FAMILY = {
    "dense": (T.dense_block_init, T.dense_block_apply, T.dense_block_decode,
              T.dense_block_prefill, T.dense_cache_spec),
    "vlm": (T.dense_block_init, T.dense_block_apply, T.dense_block_decode,
            T.dense_block_prefill, T.dense_cache_spec),
    "moe": (T.moe_block_init, T.moe_block_apply, T.moe_block_decode,
            T.moe_block_prefill, T.dense_cache_spec),
    "mla_moe": (T.mla_block_init, T.mla_block_apply, T.mla_block_decode,
                T.mla_block_prefill,
                lambda cfg, b, s: T.mla_cache_tree(cfg, b, s)),
    "ssm": (T.ssm_block_init, T.ssm_block_apply, T.ssm_block_decode,
            T.ssm_block_prefill, T.ssm_cache_spec),
    "hybrid": (T.hybrid_group_init, T.hybrid_group_apply,
               T.hybrid_group_decode, T.hybrid_group_prefill,
               T.hybrid_cache_spec),
}


def _n_blocks(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // 3
    if cfg.family == "encdec":
        return cfg.dec_layers
    return cfg.n_layers


def _n_tail(cfg: ModelConfig) -> int:
    return cfg.n_layers % 3 if cfg.family == "hybrid" else 0


# -------------------------------------------------------------------- init
def init_params(cfg: ModelConfig, key) -> Params:
    keys = split_keys(key, 8)
    p: Params = {"embed": embedding_init(keys[0], cfg.vocab, cfg.d_model,
                                         cfg.dtype),
                 "final_norm": rmsnorm_init(keys[1], cfg.d_model, cfg.dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": normal_param(
            keys[2], (cfg.vocab, cfg.d_model), cfg.dtype, "vocab_mega",
            "fsdp", scale=0.02)}
    if cfg.family == "encdec":
        binit = T.dense_block_init
        p["src_proj"] = normal_param(keys[3], (cfg.d_model, cfg.d_model),
                                     cfg.dtype, "fsdp", "out_fsdp")
        p["encoder"] = stacked_init(cfg.enc_layers,
                                    lambda k: T.enc_block_init(cfg, k), keys[4])
        p["decoder"] = stacked_init(cfg.dec_layers,
                                    lambda k: T.dec_block_init(cfg, k), keys[5])
        p["enc_norm"] = rmsnorm_init(keys[6], cfg.d_model, cfg.dtype)
        return p
    if cfg.family == "vlm":
        p["connector"] = normal_param(keys[3], (cfg.d_model, cfg.d_model),
                                      cfg.dtype, "fsdp", "out_fsdp")
    binit = _FAMILY[cfg.family][0]
    p["blocks"] = stacked_init(_n_blocks(cfg), lambda k: binit(cfg, k), keys[7])
    if _n_tail(cfg):
        p["tail"] = stacked_init(_n_tail(cfg),
                                 lambda k: T._rec_sub_init(cfg, k), keys[6])
    return p


def param_specs(cfg: ModelConfig, rules: Optional[ShardingRules] = None):
    return specs_of(init_params(cfg, None),
                    rules or ShardingRules(layout=cfg.layout))


# ----------------------------------------------------------------- forward
def _rope(cfg: ModelConfig, S: int):
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    if cfg.family == "ssm":
        return None, None
    return rope_angles(jnp.arange(S), hd, cfg.rope_theta)


def _tail_apply(cfg, p, x):
    def body(carry, lp):
        h, aux = carry
        return (T._rec_sub_apply(cfg, lp, h), aux), None
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), p["tail"])
    return x


def _backbone(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Embedded input -> final-normed hidden states (+ MoE aux loss)."""
    _, bapply, _, _, _ = _FAMILY[cfg.family]
    cos, sin = _rope(cfg, x.shape[1])
    x, aux = T.run_stack(cfg, p["blocks"], x, bapply, cos, sin)
    if _n_tail(cfg):
        x = _tail_apply(cfg, p, x)
    return rmsnorm(p["final_norm"], x), aux


def _unembed_params(cfg: ModelConfig, p: Params) -> Params:
    return p["embed"] if cfg.tie_embeddings else p["unembed"]


def _last_logits(cfg: ModelConfig, p: Params, h_last: jax.Array) -> jax.Array:
    """h_last: (B, d) -> (B, vocab)."""
    with jax.named_scope("unembed"):
        table = _unembed_params(cfg, p)["table"]
        logits = jnp.einsum("bd,vd->bv", h_last, table)
        return shard(logits, "batch", "vocab")


def loss_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]
            ) -> jax.Array:
    mask = batch.get("mask")
    if cfg.family == "encdec":
        with jax.named_scope("frontend"):
            src = jnp.einsum("bsd,de->bse", batch["src_embeds"], p["src_proj"])
        cos, sin = _rope(cfg, src.shape[1])
        enc, _ = T.run_stack(cfg, p["encoder"], src, T.enc_block_apply,
                             cos, sin)
        enc = rmsnorm(p["enc_norm"], enc)
        x = embed(p["embed"], batch["tokens"])
        cos, sin = _rope(cfg, x.shape[1])
        x, aux = T.run_stack(cfg, p["decoder"], x, T.dec_block_apply,
                             cos, sin, enc)
        h = rmsnorm(p["final_norm"], x)
    elif cfg.family == "vlm":
        with jax.named_scope("frontend"):
            prefix = jnp.einsum("bpd,de->bpe", batch["patch_embeds"],
                                p["connector"])
        x = jnp.concatenate([prefix, embed(p["embed"], batch["tokens"])],
                            axis=1)
        h, aux = _backbone(cfg, p, x)
        h = h[:, cfg.n_patches:]
    else:
        x = embed(p["embed"], batch["tokens"])
        h, aux = _backbone(cfg, p, x)
    loss = softmax_cross_entropy_chunked(_unembed_params(cfg, p), h,
                                         batch["labels"], mask,
                                         chunk=cfg.loss_chunk)
    if cfg.n_experts:
        loss = loss + cfg.aux_loss_coef * aux / max(_n_blocks(cfg), 1)
    return loss


# ----------------------------------------------------------------- prefill
def prefill_fn(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array]
               ) -> Tuple[jax.Array, Params]:
    _, _, _, bprefill, _ = _FAMILY.get(cfg.family, (None,) * 5)
    if cfg.family == "encdec":
        with jax.named_scope("frontend"):
            src = jnp.einsum("bsd,de->bse", batch["src_embeds"], p["src_proj"])
        cos, sin = _rope(cfg, src.shape[1])
        enc, _ = T.run_stack(cfg, p["encoder"], src, T.enc_block_apply,
                             cos, sin)
        enc = rmsnorm(p["enc_norm"], enc)
        x = embed(p["embed"], batch["tokens"])
        cos, sin = _rope(cfg, x.shape[1])
        x, caches = T.run_stack_prefill(cfg, p["decoder"], x,
                                        T.dec_block_prefill, cos, sin, enc)
        h = rmsnorm(p["final_norm"], x)
        return _last_logits(cfg, p, h[:, -1]), caches
    if cfg.family == "vlm":
        with jax.named_scope("frontend"):
            prefix = jnp.einsum("bpd,de->bpe", batch["patch_embeds"],
                                p["connector"])
        x = jnp.concatenate([prefix, embed(p["embed"], batch["tokens"])],
                            axis=1)
    else:
        x = embed(p["embed"], batch["tokens"])
    cos, sin = _rope(cfg, x.shape[1])
    x, caches = T.run_stack_prefill(cfg, p["blocks"], x, bprefill, cos, sin)
    if _n_tail(cfg):
        # tail recurrent layers: prefill via forward-with-state
        def body(h, lp):
            o, c = T.rglru_forward(lp["rnn"],
                                   rmsnorm(lp["ln1"], h), return_state=True)
            h = h + o
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h),
                        activation=cfg.activation)
            return h, c
        x, tail_caches = jax.lax.scan(body, x, p["tail"])
        caches = {"groups": caches, "tail": tail_caches}
    h = rmsnorm(p["final_norm"], x)
    return _last_logits(cfg, p, h[:, -1]), caches


# ------------------------------------------------------------------ decode
def decode_fn(cfg: ModelConfig, p: Params, cache: Params,
              tokens: jax.Array, pos: jax.Array
              ) -> Tuple[jax.Array, Params]:
    _, _, bdecode, _, _ = _FAMILY.get(cfg.family, (None,) * 5)
    x = embed(p["embed"], tokens)
    if cfg.family == "encdec":
        x, new_caches = T.run_stack_decode(cfg, p["decoder"], cache, x,
                                           T.dec_block_decode, pos)
    elif _n_tail(cfg):
        x, new_groups = T.run_stack_decode(cfg, p["blocks"], cache["groups"],
                                           x, bdecode, pos)
        def body(h, inp):
            lp, c = inp
            o, c = T.rglru_decode(lp["rnn"], rmsnorm(lp["ln1"], h), c)
            h = h + o
            h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h),
                        activation=cfg.activation)
            return h, c
        x, new_tail = jax.lax.scan(body, x, (p["tail"], cache["tail"]))
        new_caches = {"groups": new_groups, "tail": new_tail}
    else:
        x, new_caches = T.run_stack_decode(cfg, p["blocks"], cache, x,
                                           bdecode, pos)
    h = rmsnorm(p["final_norm"], x)
    return _last_logits(cfg, p, h[:, -1]), new_caches


# -------------------------------------------------------------- cache spec
def _stack_spec(tree, n: int):
    return jax.tree.map(
        lambda l: SpecLeaf((n,) + l.shape, l.dtype, (None,) + l.logical),
        tree, is_leaf=lambda x: isinstance(x, SpecLeaf))


def cache_spec(cfg: ModelConfig, batch: int, seq: int) -> Params:
    if cfg.family == "encdec":
        per = T.encdec_cache_spec(cfg, batch, seq)
        return _stack_spec(per, cfg.dec_layers)
    _, _, _, _, cspec = _FAMILY[cfg.family]
    per = cspec(cfg, batch, seq)
    stacked = _stack_spec(per, _n_blocks(cfg))
    if _n_tail(cfg):
        from .rglru import rglru_cache_spec
        tail = _stack_spec(rglru_cache_spec(batch, cfg.d_rnn, cfg.dtype),
                           _n_tail(cfg))
        return {"groups": stacked, "tail": tail}
    return stacked


# ------------------------------------------------------------------- Model
@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    def init(self, key) -> Params:
        return init_params(self.cfg, key)

    def loss(self, params, batch):
        return loss_fn(self.cfg, params, batch)

    def prefill(self, params, batch):
        return prefill_fn(self.cfg, params, batch)

    def decode(self, params, cache, tokens, pos):
        return decode_fn(self.cfg, params, cache, tokens, pos)

    def cache_spec(self, batch: int, seq: int):
        return cache_spec(self.cfg, batch, seq)

    def param_specs(self, rules: ShardingRules = DEFAULT_RULES):
        return param_specs(self.cfg, rules)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in set(_FAMILY) | {"encdec"}:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg)


# ----------------------------------------------------------------- steps
def make_train_step(cfg: ModelConfig, optimizer) -> Callable:
    """(state, batch) -> (state, metrics).  state = {params, opt, step}."""
    model = build_model(cfg)

    def train_step(state, batch):
        set_active_layout(cfg.layout)
        params = state["params"]
        accum = cfg.grad_accum

        def lf(p, mb):
            return model.loss(p, mb)

        if accum > 1:
            def resh(t):
                return t.reshape((accum, t.shape[0] // accum) + t.shape[1:])
            mbs = jax.tree.map(resh, batch)
            g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype), params)

            def body(carry, mb):
                tot, acc = carry
                l, g = jax.value_and_grad(lf)(params, mb)
                return (tot + l, jax.tree.map(jnp.add, acc, g)), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), mbs)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        else:
            loss, grads = jax.value_and_grad(lf)(params, batch)

        with jax.named_scope("update"):
            new_params, new_opt = optimizer.apply(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        gnorm = optimizer.last_grad_norm(new_opt)
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    model = build_model(cfg)

    def serve_step(params, cache, tokens, pos):
        set_active_layout(cfg.serve_layout)
        logits, cache = model.decode(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        set_active_layout(cfg.serve_layout)
        logits, cache = model.prefill(params, batch)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, cache

    return prefill_step


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, *, kind: str, seq_len: int,
                global_batch: int) -> Dict[str, SpecLeaf]:
    """SpecLeaf stand-ins for the step-function batch argument.

    ``kind``: train | prefill | decode.  Convert with ``shapes_of`` /
    ``sharded_shapes_of`` under the target mesh.
    """
    B, S = global_batch, seq_len
    i32 = jnp.dtype(jnp.int32)
    tok_logical = ("batch", None)

    def toks(s):
        return SpecLeaf((B, s), i32, tok_logical)

    if cfg.family == "vlm":
        text = S - cfg.n_patches
        base = {"patch_embeds": SpecLeaf((B, cfg.n_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype),
                                         ("batch", None, None)),
                "tokens": toks(text)}
        if kind == "train":
            base["labels"] = toks(text)
        return base
    if cfg.family == "encdec":
        base = {"src_embeds": SpecLeaf((B, S, cfg.d_model),
                                       jnp.dtype(cfg.dtype),
                                       ("batch", None, None)),
                "tokens": toks(S)}
        if kind == "train":
            base["labels"] = toks(S)
        return base
    base = {"tokens": toks(S)}
    if kind == "train":
        base["labels"] = toks(S)
    return base


# -------------------------------------------------------------- accounting
def count_params(cfg: ModelConfig) -> int:
    return _count_params(init_params(cfg, None))


def active_params(cfg: ModelConfig) -> int:
    """Per-token active parameters (for MODEL_FLOPS = 6 * N_active * D)."""
    total = count_params(cfg)
    if cfg.n_experts and cfg.top_k:
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        inactive = (cfg.n_experts - cfg.top_k) * per_expert * _n_blocks(cfg)
        total -= inactive
    return total
