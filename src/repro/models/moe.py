"""Mixture-of-Experts FFN: top-k routing, capacity-bounded scatter dispatch.

TPU adaptation notes (DESIGN.md §2): instead of a GShard one-hot dispatch
einsum — whose (tokens, experts, capacity) tensor is ~10 GB/device at our
shapes — tokens are *scatter*-dispatched into an (E, C, d) buffer and
*gather*-combined back.  Compute stays E*C*d*ff (≈ active-params roofline with
capacity factor ~1), memory stays O(E*C*d).  Experts are sharded over the
``model`` mesh axis (EP); GSPMD turns the data->expert resharding into
all-to-all / collective-permute traffic which the dry-run roofline surfaces.

Shared experts (DeepSeek-V2 style) are plain dense MLPs added to every token.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, use_weight
from .paramdecl import normal_param, zeros_param, split_keys
from .layers import mlp_init, mlp

Params = Dict[str, Any]


def moe_init(key, d: int, d_ff_expert: int, n_experts: int, top_k: int,
             n_shared: int, dtype) -> Params:
    kg, ke1, ke2, ke3, ks = split_keys(key, 5)
    p: Params = {
        "router": normal_param(kg, (d, n_experts), jnp.float32, "fsdp", None,
                               scale=0.02),
        "w_gate": normal_param(ke1, (n_experts, d, d_ff_expert), dtype,
                               "expert", "fsdp", "out_fsdp"),
        "w_up": normal_param(ke2, (n_experts, d, d_ff_expert), dtype,
                             "expert", "fsdp", "out_fsdp"),
        "w_down": normal_param(ke3, (n_experts, d_ff_expert, d), dtype,
                               "expert", None, "out_fsdp"),
    }
    if n_shared > 0:
        p["shared"] = mlp_init(ks, d, d_ff_expert * n_shared, dtype, gated=True)
    return p


def _route(router_w: jax.Array, x2: jax.Array, top_k: int
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x2: (T, d) -> (gate_probs (T,k), expert_idx (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * mean(frac_tokens * frac_prob)
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)
    return gate, idx, aux


def moe_ffn(p: Params, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            activation: str = "silu") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Hierarchical scatter-dispatch MoE.

    Perf iteration (deepseek cell): dispatch is *per batch row* — every
    token scatters into its own row's (E, C_row, d) buffer, so routing never
    crosses the data-sharded batch dim; the only resharding is the expert
    dim onto the ``model`` axis (all-to-all over EP, payload = activations).
    A single global (E, C, d) buffer made GSPMD all-reduce multi-GB dispatch
    state over all 256 chips (observed 5.3 TB/device/step).
    """
    with jax.named_scope("moe"):
        B, S, d = x.shape
        E = p["router"].shape[-1]
        gate, idx, aux = _route(p["router"], x.reshape(B * S, d), top_k)
        gate = gate.reshape(B, S * top_k)
        flat_e = idx.reshape(B, S * top_k)

        cap = int(max(1, round(S * top_k / E * capacity_factor)))
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (B, S*k, E)
        pos = jnp.cumsum(onehot, axis=1) - 1                   # arrival order
        pos_in_e = jnp.take_along_axis(pos, flat_e[..., None],
                                       axis=2)[..., 0]         # (B, S*k)
        keep = pos_in_e < cap                                  # overflow drops
        safe_pos = jnp.where(keep, pos_in_e, cap - 1)

        tok_ids = jnp.repeat(jnp.arange(S), top_k)             # (S*k,)
        contrib = jnp.where(keep[..., None], x[:, tok_ids, :], 0
                            ).astype(x.dtype)                  # (B, S*k, d)

        def row_scatter(c, fe, sp):
            return jnp.zeros((E, cap, d), x.dtype).at[fe, sp].add(
                c, mode="drop")

        buf = jax.vmap(row_scatter)(contrib, flat_e, safe_pos)  # (B,E,C,d)
        buf = shard(buf, "batch", "expert", None, None)

        act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
               "relu": jax.nn.relu}[activation]
        g = act(jnp.einsum("becd,edf->becf", buf,
                           use_weight(p["w_gate"], "expert", None, None)))
        u = jnp.einsum("becd,edf->becf", buf,
                       use_weight(p["w_up"], "expert", None, None))
        h = shard(g * u, "batch", "expert", None, None)
        eo = jnp.einsum("becf,efd->becd", h,
                        use_weight(p["w_down"], "expert", None, None))
        # combine: replicate expert outputs across the EP axis *before* the
        # gather (one bf16 all-gather) rather than letting GSPMD all-reduce
        # the f32 scatter-add cotangent in bwd (2x the bytes; deepseek iter 3)
        eo = shard(eo, "batch", None, None, None)

        # gather each (token, slot)'s expert output back and weight by gate
        def row_gather(e_out, fe, sp):
            return e_out[fe, sp]

        out_slots = jax.vmap(row_gather)(eo, flat_e, safe_pos)  # (B, S*k, d)
        w = (gate * keep).astype(x.dtype)

        def row_combine(slots, wgt):
            return jnp.zeros((S, d), x.dtype).at[tok_ids].add(
                slots * wgt[:, None])

        combined = jax.vmap(row_combine)(out_slots, w)
        out = combined.reshape(B, S, d)
        if "shared" in p:
            out = out + mlp(p["shared"], x, activation=activation)
        return shard(out, "batch", None, None), aux


def moe_param_count(d: int, d_ff_expert: int, n_experts: int, n_shared: int
                    ) -> Tuple[int, int]:
    """(total, active-per-token-with-top_k=1-unit) FFN params — helpers for
    the 6*N*D MODEL_FLOPS accounting."""
    per_expert = 3 * d * d_ff_expert
    total = n_experts * per_expert + d * n_experts
    shared = 3 * d * d_ff_expert * n_shared if n_shared else 0
    return total + shared, per_expert
