"""Per-family transformer blocks and the scan-over-layers assembly.

Each family provides (init, train-apply, decode-apply, cache-spec) with a
uniform signature so ``model.py`` can assemble any of the ten assigned
architectures.  Layers are stacked on a leading axis and driven by
``lax.scan`` (bounded HLO size at 126-layer scale); ``jax.checkpoint`` wraps
the scan body per the config's remat policy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, mesh_axis_sizes
from .paramdecl import SpecLeaf, split_keys, stacked_init
from .layers import (rmsnorm_init, rmsnorm, layernorm_init, layernorm,
                     mlp_init, mlp)
from .attention import (gqa_init, gqa_attend, gqa_decode, gqa_cache_spec,
                        mla_init, mla_attend, mla_decode, mla_cache_spec,
                        rope_angles, chunked_attention)
from .moe import moe_init, moe_ffn
from .ssm import (mamba2_init, mamba2_forward, mamba2_decode,
                  mamba2_cache_spec)
from .rglru import rglru_init, rglru_forward, rglru_decode, rglru_cache_spec

Params = Dict[str, Any]


def _norm_fns(cfg):
    if cfg.norm == "layernorm":
        return layernorm_init, layernorm
    return rmsnorm_init, rmsnorm


def _head_dim(cfg) -> int:
    return cfg.head_dim or cfg.d_model // cfg.n_heads


def kv_cache_logical(n_kv: int) -> Tuple[Optional[str], Optional[str]]:
    """Pick (seq_axis, head_axis) logical tags for a KV cache: shard kv heads
    over `model` when divisible, otherwise shard the sequence dimension."""
    sizes = mesh_axis_sizes()
    m = sizes.get("model", 1)
    if m > 1 and n_kv % m == 0:
        return None, "heads"
    return "kvseq", None


def _retag_cache(spec_tree: Params, n_kv: int) -> Params:
    seq_ax, head_ax = kv_cache_logical(n_kv)

    def leaf(l: SpecLeaf) -> SpecLeaf:
        if len(l.shape) == 4:   # (B, S, K, hd)
            return SpecLeaf(l.shape, l.dtype, ("batch", seq_ax, head_ax, None))
        return l
    return jax.tree.map(leaf, spec_tree,
                        is_leaf=lambda x: isinstance(x, SpecLeaf))


# ---------------------------------------------------------------- dense/GQA
def dense_block_init(cfg, key) -> Params:
    k1, k2, k3, k4 = split_keys(key, 4)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln1": ninit(k1, cfg.d_model, cfg.dtype),
        "attn": gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         _head_dim(cfg), cfg.dtype, bias=cfg.attn_bias),
        "ln2": ninit(k3, cfg.d_model, cfg.dtype),
        "mlp": mlp_init(k4, cfg.d_model, cfg.d_ff, cfg.dtype,
                        gated=cfg.gated_mlp),
    }


def dense_block_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    x = x + gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin,
                       causal=True, window=cfg.window or None,
                       chunk=cfg.attn_chunk)
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def dense_block_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = gqa_decode(p["attn"], nf(p["ln1"], x), cache, pos,
                          cfg.rope_theta, window=cfg.window or None)
    x = x + a
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, cache


def dense_cache_spec(cfg, batch: int, seq: int) -> Params:
    spec = gqa_cache_spec(batch, seq, cfg.n_kv_heads, _head_dim(cfg),
                          cfg.dtype, window=cfg.window or None)
    return _retag_cache(spec, cfg.n_kv_heads)


# --------------------------------------------------------------------- MoE
def moe_block_init(cfg, key) -> Params:
    k1, k2, k3, k4 = split_keys(key, 4)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln1": ninit(k1, cfg.d_model, cfg.dtype),
        "attn": gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         _head_dim(cfg), cfg.dtype),
        "ln2": ninit(k3, cfg.d_model, cfg.dtype),
        "moe": moe_init(k4, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                        cfg.top_k, cfg.n_shared_experts, cfg.dtype),
    }


def moe_block_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    x = x + gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin,
                       chunk=cfg.attn_chunk)
    h, aux = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                     capacity_factor=cfg.capacity_factor,
                     activation=cfg.activation)
    return x + h, aux


def moe_block_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = gqa_decode(p["attn"], nf(p["ln1"], x), cache, pos,
                          cfg.rope_theta)
    x = x + a
    h, _ = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                   capacity_factor=cfg.capacity_factor,
                   activation=cfg.activation)
    return x + h, cache


# ----------------------------------------------------------------- MLA+MoE
def mla_block_init(cfg, key) -> Params:
    k1, k2, k3, k4 = split_keys(key, 4)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln1": ninit(k1, cfg.d_model, cfg.dtype),
        "attn": mla_init(k2, cfg.d_model, cfg.n_heads, cfg.dtype,
                         q_lora=cfg.q_lora, kv_lora=cfg.kv_lora,
                         qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
                         v_dim=cfg.v_head_dim),
        "ln2": ninit(k3, cfg.d_model, cfg.dtype),
        "moe": moe_init(k4, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
                        cfg.top_k, cfg.n_shared_experts, cfg.dtype),
    }


def mla_block_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    S = x.shape[1]
    positions = jnp.arange(S)
    x = x + mla_attend(p["attn"], nf(p["ln1"], x), positions, cfg.rope_theta,
                       chunk=cfg.attn_chunk)
    h, aux = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                     capacity_factor=cfg.capacity_factor,
                     activation=cfg.activation)
    return x + h, aux


def mla_block_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = mla_decode(p["attn"], nf(p["ln1"], x), cache, pos,
                          cfg.rope_theta)
    x = x + a
    h, _ = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                   capacity_factor=cfg.capacity_factor,
                   activation=cfg.activation)
    return x + h, cache


def mla_cache_tree(cfg, batch: int, seq: int) -> Params:
    return mla_cache_spec(batch, seq, cfg.kv_lora, cfg.qk_rope, cfg.dtype)


# --------------------------------------------------------------------- SSM
def ssm_block_init(cfg, key) -> Params:
    k1, k2 = split_keys(key, 2)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln": ninit(k1, cfg.d_model, cfg.dtype),
        "ssm": mamba2_init(k2, cfg.d_model, cfg.ssm_state, cfg.dtype,
                           expand=cfg.ssm_expand),
    }


def ssm_block_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    x = x + mamba2_forward(p["ssm"], nf(p["ln"], x), chunk=cfg.ssm_chunk)
    return x, jnp.zeros((), jnp.float32)


def ssm_block_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    h, cache = mamba2_decode(p["ssm"], nf(p["ln"], x), cache)
    return x + h, cache


def ssm_cache_spec(cfg, batch: int, seq: int) -> Params:
    return mamba2_cache_spec(batch, cfg.d_model, cfg.ssm_state, cfg.dtype,
                             expand=cfg.ssm_expand)


# ------------------------------------------------------------ hybrid group
# RecurrentGemma pattern: (recurrent, recurrent, local-attn) repeating; each
# sub-block pairs with its own MLP.
def _rec_sub_init(cfg, key) -> Params:
    k1, k2, k3, k4 = split_keys(key, 4)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln1": ninit(k1, cfg.d_model, cfg.dtype),
        "rnn": rglru_init(k2, cfg.d_model, cfg.d_rnn, cfg.dtype),
        "ln2": ninit(k3, cfg.d_model, cfg.dtype),
        "mlp": mlp_init(k4, cfg.d_model, cfg.d_ff, cfg.dtype, gated=True),
    }


def _attn_sub_init(cfg, key) -> Params:
    k1, k2, k3, k4 = split_keys(key, 4)
    ninit, _ = _norm_fns(cfg)
    return {
        "ln1": ninit(k1, cfg.d_model, cfg.dtype),
        "attn": gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         _head_dim(cfg), cfg.dtype),
        "ln2": ninit(k3, cfg.d_model, cfg.dtype),
        "mlp": mlp_init(k4, cfg.d_model, cfg.d_ff, cfg.dtype, gated=True),
    }


def hybrid_group_init(cfg, key) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    return {"rec1": _rec_sub_init(cfg, k1), "rec2": _rec_sub_init(cfg, k2),
            "attn": _attn_sub_init(cfg, k3)}


def _rec_sub_apply(cfg, p, x):
    _, nf = _norm_fns(cfg)
    x = x + rglru_forward(p["rnn"], nf(p["ln1"], x))
    return x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)


def _attn_sub_apply(cfg, p, x, cos, sin):
    _, nf = _norm_fns(cfg)
    x = x + gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin, causal=True,
                       window=cfg.window, chunk=cfg.attn_chunk)
    return x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)


def hybrid_group_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    x = _rec_sub_apply(cfg, p["rec1"], x)
    x = _rec_sub_apply(cfg, p["rec2"], x)
    x = _attn_sub_apply(cfg, p["attn"], x, cos, sin)
    return x, jnp.zeros((), jnp.float32)


def hybrid_group_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    new_cache = {}
    for name in ("rec1", "rec2"):
        sp = p[name]
        h, new_cache[name] = rglru_decode(sp["rnn"], nf(sp["ln1"], x),
                                          cache[name])
        x = x + h
        x = x + mlp(sp["mlp"], nf(sp["ln2"], x), activation=cfg.activation)
    sp = p["attn"]
    a, new_cache["attn"] = gqa_decode(sp["attn"], nf(sp["ln1"], x),
                                      cache["attn"], pos, cfg.rope_theta,
                                      window=cfg.window)
    x = x + a
    x = x + mlp(sp["mlp"], nf(sp["ln2"], x), activation=cfg.activation)
    return x, new_cache


def hybrid_cache_spec(cfg, batch: int, seq: int) -> Params:
    attn_spec = gqa_cache_spec(batch, seq, cfg.n_kv_heads, _head_dim(cfg),
                               cfg.dtype, window=cfg.window)
    return {
        "rec1": rglru_cache_spec(batch, cfg.d_rnn, cfg.dtype),
        "rec2": rglru_cache_spec(batch, cfg.d_rnn, cfg.dtype),
        "attn": _retag_cache(attn_spec, cfg.n_kv_heads),
    }


# ------------------------------------------------------------------ encdec
def enc_block_init(cfg, key) -> Params:
    p = dense_block_init(cfg, key)
    return p


def enc_block_apply(cfg, p, x, cos, sin) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    x = x + gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin, causal=False,
                       chunk=cfg.attn_chunk)
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def dec_block_init(cfg, key) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    ninit, _ = _norm_fns(cfg)
    p = dense_block_init(cfg, k1)
    p["ln_x"] = ninit(k2, cfg.d_model, cfg.dtype)
    p["xattn"] = gqa_init(k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          _head_dim(cfg), cfg.dtype)
    return p


def _cross_attend(cfg, p, x, enc_k, enc_v):
    with jax.named_scope("xattn"):
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        q = shard(q, "batch", None, "heads", None)
        o = chunked_attention(q, enc_k, enc_v, causal=False,
                              chunk=cfg.attn_chunk)
        return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def enc_kv(p_xattn, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_xattn["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_xattn["wv"])
    return shard(k, "batch", None, "heads", None), \
        shard(v, "batch", None, "heads", None)


def dec_block_apply(cfg, p, x, cos, sin, enc_out) -> Tuple[jax.Array, jax.Array]:
    _, nf = _norm_fns(cfg)
    x = x + gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin, causal=True,
                       chunk=cfg.attn_chunk)
    k, v = enc_kv(p["xattn"], enc_out)
    x = x + _cross_attend(cfg, p["xattn"], nf(p["ln_x"], x), k, v)
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def dec_block_decode(cfg, p, x, cache, pos) -> Tuple[jax.Array, Params]:
    """cache: {"k","v" (self), "xk","xv" (cross, precomputed at prefill)}."""
    _, nf = _norm_fns(cfg)
    a, self_cache = gqa_decode(p["attn"], nf(p["ln1"], x),
                               {"k": cache["k"], "v": cache["v"]}, pos,
                               cfg.rope_theta)
    x = x + a
    from .attention import decode_attention
    with jax.named_scope("xattn"):
        q = jnp.einsum("bsd,dhk->bshk", nf(p["ln_x"], x), p["xattn"]["wq"])
        o = decode_attention(q, cache["xk"], cache["xv"],
                             jnp.asarray(cache["xk"].shape[1]))
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["xattn"]["wo"])
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, {**self_cache, "xk": cache["xk"], "xv": cache["xv"]}


def encdec_cache_spec(cfg, batch: int, seq: int) -> Params:
    self_spec = _retag_cache(
        gqa_cache_spec(batch, seq, cfg.n_kv_heads, _head_dim(cfg), cfg.dtype),
        cfg.n_kv_heads)
    cross_spec = _retag_cache(
        gqa_cache_spec(batch, cfg.cross_len or seq, cfg.n_kv_heads,
                       _head_dim(cfg), cfg.dtype), cfg.n_kv_heads)
    return {"k": self_spec["k"], "v": self_spec["v"],
            "xk": cross_spec["k"], "xv": cross_spec["v"]}


# ----------------------------------------------------------------- prefill
def dense_block_prefill(cfg, p, x, cos, sin) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin, causal=True,
                          window=cfg.window or None, chunk=cfg.attn_chunk,
                          return_cache=True)
    x = x + a
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, cache


def moe_block_prefill(cfg, p, x, cos, sin) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin,
                          chunk=cfg.attn_chunk, return_cache=True)
    x = x + a
    h, _ = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                   capacity_factor=cfg.capacity_factor,
                   activation=cfg.activation)
    return x + h, cache


def mla_block_prefill(cfg, p, x, cos, sin) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    S = x.shape[1]
    positions = jnp.arange(S)
    a, cache = mla_attend(p["attn"], nf(p["ln1"], x), positions,
                          cfg.rope_theta, chunk=cfg.attn_chunk,
                          return_cache=True)
    x = x + a
    h, _ = moe_ffn(p["moe"], nf(p["ln2"], x), top_k=cfg.top_k,
                   capacity_factor=cfg.capacity_factor,
                   activation=cfg.activation)
    return x + h, cache


def ssm_block_prefill(cfg, p, x, cos, sin) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    h, cache = mamba2_forward(p["ssm"], nf(p["ln"], x), chunk=cfg.ssm_chunk,
                              return_state=True)
    return x + h, cache


def hybrid_group_prefill(cfg, p, x, cos, sin) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    cache = {}
    for name in ("rec1", "rec2"):
        sp = p[name]
        h, cache[name] = rglru_forward(sp["rnn"], nf(sp["ln1"], x),
                                       return_state=True)
        x = x + h
        x = x + mlp(sp["mlp"], nf(sp["ln2"], x), activation=cfg.activation)
    sp = p["attn"]
    a, cache["attn"] = gqa_attend(sp["attn"], nf(sp["ln1"], x), cos, sin,
                                  causal=True, window=cfg.window,
                                  chunk=cfg.attn_chunk, return_cache=True)
    x = x + a
    x = x + mlp(sp["mlp"], nf(sp["ln2"], x), activation=cfg.activation)
    return x, cache


def dec_block_prefill(cfg, p, x, cos, sin, enc_out) -> Tuple[jax.Array, Params]:
    _, nf = _norm_fns(cfg)
    a, cache = gqa_attend(p["attn"], nf(p["ln1"], x), cos, sin, causal=True,
                          chunk=cfg.attn_chunk, return_cache=True)
    x = x + a
    xk, xv = enc_kv(p["xattn"], enc_out)
    x = x + _cross_attend(cfg, p["xattn"], nf(p["ln_x"], x), xk, xv)
    x = x + mlp(p["mlp"], nf(p["ln2"], x), activation=cfg.activation)
    return x, {**cache, "xk": xk, "xv": xv}


def run_stack_prefill(cfg, stacked: Params, x: jax.Array, prefill_fn,
                      cos, sin, *extra) -> Tuple[jax.Array, Params]:
    """scan layers, emitting each layer's cache as a stacked ys tree."""
    def body(h, lp):
        h, cache = prefill_fn(cfg, lp, h, cos, sin, *extra)
        return h, cache

    x, caches = jax.lax.scan(body, x, stacked)
    return x, caches


# ------------------------------------------------------------ scan drivers
def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat == "offload":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["residual"],
                offload_src="device", offload_dst="pinned_host"))
    return jax.checkpoint(fn)      # "full": save nothing


def run_stack(cfg, stacked: Params, x: jax.Array, apply_fn,
              cos, sin, *extra) -> Tuple[jax.Array, jax.Array]:
    """scan(stacked layer params) with remat; returns (x, summed aux)."""
    def body(carry, lp):
        h, aux = carry
        h, a = apply_fn(cfg, lp, h, cos, sin, *extra)
        return (h, aux + a), None

    if cfg.scan_layers:
        body_w = _remat_wrap(cfg, body)
        (x, aux), _ = jax.lax.scan(body_w, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
        return x, aux
    n = jax.tree.leaves(stacked)[0].shape[0]
    aux = jnp.zeros((), jnp.float32)
    fn = _remat_wrap(cfg, lambda c, lp: body(c, lp)[0])
    carry = (x, aux)
    for i in range(n):
        lp = jax.tree.map(lambda t: t[i], stacked)
        with jax.named_scope(f"layer{i}"):    # per-layer task->layer mapping
            carry = fn(carry, lp)
    return carry


def run_stack_decode(cfg, stacked: Params, caches: Params, x: jax.Array,
                     decode_fn, pos) -> Tuple[jax.Array, Params]:
    """scan over (layer params, layer cache); returns (x, new caches)."""
    def body(h, inp):
        lp, cache = inp
        h, new_cache = decode_fn(cfg, lp, h, cache, pos)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches
