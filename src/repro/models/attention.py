"""Attention blocks: GQA, MLA (DeepSeek-V2), local-window, and decode paths.

Training/prefill attention is *flash-style chunked*: a ``lax.scan`` over KV
blocks with streaming softmax, so the (S, S) score matrix never materializes
(HBM footprint O(S * chunk)).  This is the pure-XLA analogue of the Pallas
flash kernel in ``repro/kernels/flash_attention.py`` (same math, same oracle).

Decode attention reads the KV cache (one new token per step).  MLA decode uses
the *absorbed* formulation: queries are projected into the compressed KV space
so the cache stays (S, kv_lora + rope_dim) per token — the whole point of MLA.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, use_weight
from .paramdecl import normal_param, zeros_param, ones_param, split_keys

Params = Dict[str, Any]

NEG_INF = -2.0 ** 30   # mask value safe in bf16 accumulation


# --------------------------------------------------------------------- rope
def rope_angles(positions: jax.Array, head_dim: int, theta: float = 10000.0
                ) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        c = cos[None, :, None, :].astype(x.dtype)
        s = sin[None, :, None, :].astype(x.dtype)
    else:
        c = cos[:, :, None, :].astype(x.dtype)
        s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# ------------------------------------------------- flash-style core (train)
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, window: Optional[int] = None,
                      chunk: int = 1024, q_offset: int = 0) -> jax.Array:
    """Streaming-softmax attention over KV chunks.

    q: (B, Sq, H, hd);  k, v: (B, Sk, K, hd) with H % K == 0 (GQA).
    ``window`` limits attention to the last ``window`` keys (local attention).
    ``q_offset`` is the absolute position of q[0] (for prefill continuation).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, K, hd_v = k.shape[1], k.shape[2], v.shape[3]
    G = H // K                                     # queries per kv head
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Sk)
    nchunk = (Sk + chunk - 1) // chunk
    pad = nchunk * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, K, hd_v).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, K, G, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        acc, m, denom = carry                      # (B,Sq,K,G,hd), (B,Sq,K,G), _
        kb, vb, cidx = inp                         # (B,chunk,K,hd) x2, scalar
        k_pos = cidx * chunk + jnp.arange(chunk)
        # scores stay in the compute dtype (bf16 on TPU): halves the dominant
        # HBM traffic of the score chain; the running max / denominator
        # statistics stay f32 (flash-kernel numerics; Perf iteration 3)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb) * jnp.asarray(
            scale, q.dtype)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= k_pos[None, :] < Sk                # padding
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, :, None, None, :], s,
                      jnp.asarray(NEG_INF, s.dtype))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1).astype(jnp.float32))
        p = jnp.exp(s - m_new[..., None].astype(s.dtype))
        corr = jnp.exp(m - m_new)
        denom = denom * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), vb)
        acc = acc * corr[..., None].astype(acc.dtype) + pv
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, Sq, K, G, hd_v), v.dtype)
    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    (acc, m, denom), _ = jax.lax.scan(
        body, (acc0, m0, d0), (kc, vc, jnp.arange(nchunk)))
    denom = jnp.maximum(denom, 1e-20)
    out = acc / denom[..., None].astype(acc.dtype)
    return out.reshape(B, Sq, H, hd_v)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, window: Optional[int] = None
                     ) -> jax.Array:
    """One-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); ``length``: scalar or (B,) count of
    valid cache entries *including* the current token.
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(S)
    ln = jnp.asarray(length)
    ln = ln[:, None] if ln.ndim == 1 else ln[None, None]
    valid = pos[None, :] < ln                       # (B or 1, S)
    if window is not None:
        valid &= pos[None, :] >= ln - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(B, 1, H, hd)


# ----------------------------------------------------------------- GQA block
def gqa_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype,
             *, bias: bool = False) -> Params:
    kq, kk, kv, ko = split_keys(key, 4)
    p: Params = {
        "wq": normal_param(kq, (d, n_heads, head_dim), dtype,
                           "fsdp", "heads", "out_fsdp"),
        "wk": normal_param(kk, (d, n_kv, head_dim), dtype, "fsdp", "heads",
                           "out_fsdp"),
        "wv": normal_param(kv, (d, n_kv, head_dim), dtype, "fsdp", "heads",
                           "out_fsdp"),
        "wo": normal_param(ko, (n_heads, head_dim, d), dtype,
                           "heads", None, "out_fsdp"),
    }
    if bias:
        p["bq"] = zeros_param(None if key is None else kq,
                              (n_heads, head_dim), dtype, "heads", None)
    return p


def gqa_qkv(p: Params, x: jax.Array, cos, sin) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, use_weight(p["wq"], None, "heads", None))
    k = jnp.einsum("bsd,dhk->bshk", x, use_weight(p["wk"], None, "heads", None))
    v = jnp.einsum("bsd,dhk->bshk", x, use_weight(p["wv"], None, "heads", None))
    if "bq" in p:
        q = q + p["bq"]
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    return q, k, v


def gqa_attend(p: Params, x: jax.Array, cos, sin, *, causal: bool = True,
               window: Optional[int] = None, chunk: int = 1024,
               return_cache: bool = False):
    with jax.named_scope("attn"):
        q, k, v = gqa_qkv(p, x, cos, sin)
        o = chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        out = shard(out, "batch", None, None)
        if not return_cache:
            return out
        if window is not None and k.shape[1] >= window:
            S = k.shape[1]
            k = jnp.roll(k[:, S - window:], S % window, axis=1)
            v = jnp.roll(v[:, S - window:], S % window, axis=1)
        return out, {"k": k, "v": v}


def gqa_decode(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
               theta: float, *, window: Optional[int] = None
               ) -> Tuple[jax.Array, Params]:
    """x: (B, 1, d); cache {"k","v"}: (B, S, K, hd); pos: scalar index."""
    with jax.named_scope("attn"):
        positions = jnp.asarray(pos)[None]                      # (1,)
        cos, sin = rope_angles(positions, p["wq"].shape[-1], theta)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bq" in p:
            q = q + p["bq"]
        q = apply_rope(q, cos[None], sin[None])
        k = apply_rope(k, cos[None], sin[None])
        if window is None:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
            length = pos + 1
            o = decode_attention(q, kc, vc, length)
        else:
            # ring-buffer window cache (long-context decode)
            slot = jnp.mod(pos, cache["k"].shape[1])
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            length = jnp.minimum(pos + 1, cache["k"].shape[1])
            o = decode_attention(q, kc, vc, length)   # ring: all valid entries
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, {"k": kc, "v": vc}


def gqa_cache_spec(batch: int, seq: int, n_kv: int, head_dim: int, dtype,
                   window: Optional[int] = None) -> Params:
    from .paramdecl import SpecLeaf
    S = min(seq, window) if window else seq
    shape = (batch, S, n_kv, head_dim)
    logical = ("batch", None, "heads", None)
    return {"k": SpecLeaf(shape, jnp.dtype(dtype), logical),
            "v": SpecLeaf(shape, jnp.dtype(dtype), logical)}


# ----------------------------------------------------------------- MLA block
def mla_init(key, d: int, n_heads: int, dtype, *, q_lora: int = 1536,
             kv_lora: int = 512, qk_nope: int = 128, qk_rope: int = 64,
             v_dim: int = 128) -> Params:
    k1, k2, k3, k4, k5, k6 = split_keys(key, 6)
    return {
        "wq_a": normal_param(k1, (d, q_lora), dtype, "fsdp", "out_fsdp"),
        "q_norm": ones_param(None if key is None else k1, (q_lora,), dtype, None),
        "wq_b": normal_param(k2, (q_lora, n_heads, qk_nope + qk_rope), dtype,
                             "fsdp", "heads", "out_fsdp"),
        "wkv_a": normal_param(k3, (d, kv_lora + qk_rope), dtype, "fsdp",
                              "out_fsdp"),
        "kv_norm": ones_param(None if key is None else k3, (kv_lora,), dtype, None),
        "wk_b": normal_param(k4, (kv_lora, n_heads, qk_nope), dtype,
                             "fsdp", "heads", "out_fsdp"),
        "wv_b": normal_param(k5, (kv_lora, n_heads, v_dim), dtype,
                             "fsdp", "heads", "out_fsdp"),
        "wo": normal_param(k6, (n_heads, v_dim, d), dtype, "heads", None,
                           "out_fsdp"),
    }


def _rms(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def mla_attend(p: Params, x: jax.Array, positions: jax.Array, theta: float,
               *, chunk: int = 1024, return_cache: bool = False):
    """Training/prefill MLA: expand compressed KV, run chunked attention."""
    with jax.named_scope("attn"):
        B, S, _ = x.shape
        qk_rope = p["wq_b"].shape[-1] - p["wk_b"].shape[-1]
        kv_lora = p["wk_b"].shape[0]
        q = jnp.einsum("bsd,dl->bsl", x, p["wq_a"])
        q = _rms(q, p["q_norm"])
        q = jnp.einsum("bsl,lhk->bshk", q, p["wq_b"])
        q_nope, q_rope = q[..., :-qk_rope], q[..., -qk_rope:]
        kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
        c_kv, k_rope = kv[..., :kv_lora], kv[..., kv_lora:]
        c_kv = _rms(c_kv, p["kv_norm"])
        cos, sin = rope_angles(positions, qk_rope, theta)
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rope)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"])
        v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"])
        H = k_nope.shape[2]
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (B, S, H, qk_rope))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        qfull = shard(qfull, "batch", None, "heads", None)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        o = chunked_attention(qfull, k, v, causal=True, chunk=chunk)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        out = shard(out, "batch", None, None)
        if not return_cache:
            return out
        return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
               theta: float) -> Tuple[jax.Array, Params]:
    """Absorbed MLA decode: cache stores (c_kv, k_rope) only.

    score_h = q_nope_h^T Wk_b_h c_kv  +  q_rope_h^T k_rope
    out_h   = (attn @ c_kv) Wv_b_h
    """
    with jax.named_scope("attn"):
        B = x.shape[0]
        qk_rope = p["wq_b"].shape[-1] - p["wk_b"].shape[-1]
        kv_lora = p["wk_b"].shape[0]
        q = _rms(jnp.einsum("bsd,dl->bsl", x, p["wq_a"]), p["q_norm"])
        q = jnp.einsum("bsl,lhk->bshk", q, p["wq_b"])         # (B,1,H,nope+rope)
        q_nope, q_rope = q[..., :-qk_rope], q[..., -qk_rope:]
        kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])          # (B,1,lora+rope)
        c_new, kr_new = kv[..., :kv_lora], kv[..., kv_lora:]
        c_new = _rms(c_new, p["kv_norm"])
        positions = jnp.asarray(pos)[None]
        cos, sin = rope_angles(positions, qk_rope, theta)
        q_rope = apply_rope(q_rope, cos[None], sin[None])
        kr_new = apply_rope(kr_new[:, :, None, :], cos[None], sin[None])[:, :, 0]
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, axis=1)
        krc = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos,
                                                  axis=1)
        # absorb q into compressed space: (B,H,lora)
        q_abs = jnp.einsum("bshk,lhk->bhl", q_nope, p["wk_b"])
        scores = (jnp.einsum("bhl,bsl->bhs", q_abs, ckv)
                  + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], krc)
                  ).astype(jnp.float32)
        scale = 1.0 / math.sqrt(p["wq_b"].shape[-1])
        S = ckv.shape[1]
        valid = jnp.arange(S)[None, :] < (pos + 1)
        scores = jnp.where(valid[:, None, :], scores * scale, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
        o_c = jnp.einsum("bhs,bsl->bhl", w, ckv)               # (B,H,lora)
        o = jnp.einsum("bhl,lhk->bhk", o_c, p["wv_b"])         # (B,H,v)
        out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
        return out, {"c_kv": ckv, "k_rope": krc}


def mla_cache_spec(batch: int, seq: int, kv_lora: int, qk_rope: int, dtype
                   ) -> Params:
    from .paramdecl import SpecLeaf
    return {
        "c_kv": SpecLeaf((batch, seq, kv_lora), jnp.dtype(dtype),
                         ("batch", None, None)),
        "k_rope": SpecLeaf((batch, seq, qk_rope), jnp.dtype(dtype),
                           ("batch", None, None)),
    }
