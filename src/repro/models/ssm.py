"""Mamba-2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

TPU adaptation (DESIGN.md §2): the CUDA Mamba kernel is a fused warp-level
scan; the TPU-native formulation is the SSD *chunked* algorithm — quadratic
attention-like compute inside fixed-size chunks (MXU-friendly (Q,Q) matmuls)
with a sequential inter-chunk state recurrence (``lax.scan``).  Decode carries
(conv window, SSM state) and is O(1) per token — which is why mamba2 runs the
``long_500k`` cell that dense-attention archs skip.

Simplifications vs the reference CUDA implementation (documented):
  * n_groups = 1 (B/C shared across heads),
  * the short causal conv applies to the x branch only,
  * gate normalization is RMSNorm(y * silu(z)).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import shard, use_weight
from .paramdecl import normal_param, zeros_param, ones_param, split_keys
from .layers import rmsnorm_init, rmsnorm

Params = Dict[str, Any]

CONV_K = 4         # short depthwise conv kernel width
HEAD_P = 64        # SSD head dim


def mamba2_init(key, d: int, d_state: int, dtype, *, expand: int = 2) -> Params:
    d_inner = expand * d
    n_heads = d_inner // HEAD_P
    k1, k2, k3, k4, k5, k6, k7 = split_keys(key, 7)
    return {
        "wz": normal_param(k1, (d, d_inner), dtype, "fsdp", "ff_mega"),
        "wx": normal_param(k2, (d, d_inner), dtype, "fsdp", "ff_mega"),
        "wB": normal_param(k3, (d, d_state), dtype, "fsdp", "out_fsdp"),
        "wC": normal_param(k4, (d, d_state), dtype, "fsdp", "out_fsdp"),
        "w_dt": normal_param(k5, (d, n_heads), dtype, "fsdp", "heads"),
        "dt_bias": zeros_param(k5, (n_heads,), jnp.float32, "heads"),
        "A_log": zeros_param(k5, (n_heads,), jnp.float32, "heads"),
        "D": ones_param(k5, (n_heads,), jnp.float32, "heads"),
        "conv": normal_param(k6, (CONV_K, d_inner), dtype, None, "heads",
                             scale=0.5),
        "norm": rmsnorm_init(k7, d_inner, dtype),
        "w_out": normal_param(k7, (d_inner, d), dtype, "heads", "out_fsdp"),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds.  x: (B,S,D); kernel: (K,D)."""
    out = x * kernel[-1]
    for i in range(1, CONV_K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None, :]
        out = out + shifted * kernel[CONV_K - 1 - i]
    return out


def mamba2_forward(p: Params, x: jax.Array, *, chunk: int = 128,
                   return_state: bool = False):
    """x: (B, S, d) -> (B, S, d) via the SSD chunked algorithm."""
    with jax.named_scope("ssm"):
        B_, S, d = x.shape
        d_inner = p["wx"].shape[-1]
        H = d_inner // HEAD_P
        N = p["wB"].shape[-1]
        z = jnp.einsum("bsd,de->bse", x, use_weight(p["wz"], None, "heads"))
        xb_pre = jnp.einsum("bsd,de->bse", x,
                            use_weight(p["wx"], None, "heads"))
        xb = jax.nn.silu(_causal_conv(xb_pre, p["conv"]))
        Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
        Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
        dt = jax.nn.softplus(
            jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
            + p["dt_bias"])
        A = -jnp.exp(p["A_log"])                             # (H,), negative
        dA = dt * A                                          # (B,S,H) log-decay

        X = xb.reshape(B_, S, H, HEAD_P)
        Xe = (X * dt[..., None].astype(X.dtype))             # dt-scaled input

        chunk = min(chunk, S)
        nc = (S + chunk - 1) // chunk
        pad = nc * chunk - S
        if pad:
            X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Xe = jnp.pad(Xe, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))

        def to_chunks(t):
            return t.reshape((B_, nc, chunk) + t.shape[2:]).transpose(
                (1, 0, 2) + tuple(range(3, t.ndim + 1)))

        Xc, Xec, Bc, Cc = map(to_chunks, (X, Xe, Bm, Cm))
        dAc = to_chunks(dA)

        def body(state, inp):
            xq, xe, bq, cq, da = inp        # (B,Q,H,P),(B,Q,H,P),(B,Q,N)x2,(B,Q,H)
            cum = jnp.cumsum(da, axis=1)                       # (B,Q,H)
            # intra-chunk (attention-like) term
            seg = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H) i,j
            Q = xq.shape[1]
            causal = jnp.tril(jnp.ones((Q, Q), bool))
            L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
            scores = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32),
                                bq.astype(jnp.float32))
            M = (scores[..., None] * L).astype(xq.dtype)       # (B,Q,Q,H)
            y_intra = jnp.einsum("bijh,bjhp->bihp", M, xe)
            # inter-chunk term from carried state
            decay_in = jnp.exp(cum).astype(xq.dtype)           # (B,Q,H)
            y_inter = jnp.einsum("bin,bhpn->bihp", cq, state) \
                * decay_in[..., None]
            # state update
            a_all = jnp.exp(cum[:, -1])                        # (B,H)
            w = jnp.exp(cum[:, -1:, :] - cum).astype(xq.dtype)  # decay j..end
            state = state * a_all[:, :, None, None].astype(state.dtype) \
                + jnp.einsum("bjn,bjhp,bjh->bhpn", bq, xe, w)
            y = y_intra + y_inter + xq * p["D"][None, None, :, None].astype(
                xq.dtype)
            return state, y

        state0 = jnp.zeros((B_, H, HEAD_P, N), x.dtype)
        state_f, Yc = jax.lax.scan(body, state0, (Xc, Xec, Bc, Cc, dAc))
        Y = Yc.transpose(1, 0, 2, 3, 4).reshape(B_, nc * chunk, H, HEAD_P)
        Y = Y[:, :S].reshape(B_, S, d_inner)
        Y = rmsnorm(p["norm"], Y * jax.nn.silu(z))
        out = jnp.einsum("bse,ed->bsd", Y,
                         use_weight(p["w_out"], "heads", None))
        out = shard(out, "batch", None, None)
        if not return_state:
            return out
        tail = jnp.pad(xb_pre, ((0, 0), (CONV_K - 1, 0), (0, 0)))[
            :, S:S + CONV_K - 1, :]
        return out, {"conv": tail, "state": state_f}


def mamba2_decode(p: Params, x: jax.Array, cache: Params
                  ) -> Tuple[jax.Array, Params]:
    """One-token step.  x: (B, 1, d); cache: {"conv": (B, K-1, d_inner),
    "state": (B, H, P, N)}.  O(1) in sequence length."""
    with jax.named_scope("ssm"):
        B_ = x.shape[0]
        d_inner = p["wx"].shape[-1]
        H = d_inner // HEAD_P
        z = jnp.einsum("bsd,de->bse", x, p["wz"])[:, 0]
        xb = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0]       # (B, d_inner)
        window = jnp.concatenate([cache["conv"], xb[:, None, :]], axis=1)
        conv_out = jnp.einsum("bke,ke->be", window, p["conv"].astype(window.dtype))
        xb = jax.nn.silu(conv_out)
        Bt = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
        Ct = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
        dt = jax.nn.softplus(
            jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)[:, 0]
            + p["dt_bias"])                                    # (B,H)
        A = -jnp.exp(p["A_log"])
        a = jnp.exp(dt * A).astype(cache["state"].dtype)       # (B,H)
        X = xb.reshape(B_, H, HEAD_P)
        Xe = X * dt[..., None].astype(X.dtype)
        state = cache["state"] * a[:, :, None, None] \
            + jnp.einsum("bn,bhp->bhpn", Bt, Xe)
        y = jnp.einsum("bn,bhpn->bhp", Ct, state) \
            + X * p["D"][None, :, None].astype(X.dtype)
        y = y.reshape(B_, d_inner)
        y = rmsnorm(p["norm"], y * jax.nn.silu(z))
        out = jnp.einsum("be,ed->bd", y, p["w_out"])[:, None, :]
        return out, {"conv": window[:, 1:], "state": state}


def mamba2_cache_spec(batch: int, d: int, d_state: int, dtype, *,
                      expand: int = 2) -> Params:
    from .paramdecl import SpecLeaf
    d_inner = expand * d
    H = d_inner // HEAD_P
    return {
        "conv": SpecLeaf((batch, CONV_K - 1, d_inner), jnp.dtype(dtype),
                         ("batch", None, "heads")),
        "state": SpecLeaf((batch, H, HEAD_P, d_state), jnp.dtype(dtype),
                          ("batch", "heads", None, None)),
    }
