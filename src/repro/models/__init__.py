from .model import (ModelConfig, build_model, Model, init_params, param_specs,
                    make_train_step, make_serve_step, make_prefill_step,
                    input_specs, cache_spec, count_params, active_params)
from .paramdecl import (SpecLeaf, specs_of, shapes_of, sharded_shapes_of,
                        split_keys, stacked_init)

__all__ = ["ModelConfig", "build_model", "Model", "init_params",
           "param_specs", "make_train_step", "make_serve_step",
           "make_prefill_step", "input_specs", "cache_spec", "count_params",
           "active_params", "SpecLeaf", "specs_of", "shapes_of",
           "sharded_shapes_of", "split_keys", "stacked_init"]
