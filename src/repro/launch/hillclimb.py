import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run one cell with config overrides, tagged output.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch tinyllama-1.1b \
        --shape train_4k --tag iter2 --set layout=dp --set remat=dots
"""

import argparse


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def main() -> None:
    from repro.configs import registry
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if overrides:
        cfg = cfg.with_(**overrides)
    print(f"overrides: {overrides}")
    run_cell(args.arch, args.shape, args.mesh == "multi",
             out_dir=args.out, cfg_override=cfg, tag=args.tag)


if __name__ == "__main__":
    main()
