import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run one cell with config overrides, tagged output.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch tinyllama-1.1b \
        --shape train_4k --tag iter2 --set layout=dp --set remat=dots

With ``--search-whatif N`` the driver instead compiles the cell once and
greedily hill-climbs the *optimization registry* (repro.core.optimize):
every default-constructible registered optimization is a candidate, and the
best-stack-so-far grows one optimization per round (at most N) while the
predicted makespan keeps dropping.  Extra candidates with parameters come
from repeatable ``--candidate name:param=value`` specs.

Before searching, the driver prints the opportunity-ranking table
(repro.analysis: per-candidate Amdahl speedup bound through the real
simulator, critical-path share, and the realized depth-1 speedup), orders
the search best-headroom-first, and skips candidates whose bound proves
they cannot improve the scenario — the table says which and why.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch tinyllama-1.1b \
        --shape train_4k --tag whatif3 --search-whatif 3 \
        --candidate dgc:compression=0.01
"""

import argparse
import json


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def search_whatif(args, cfg) -> None:
    """Greedy registry search over the compiled step's dependency graph."""
    from repro.core.costmodel import CostModel
    from repro.core.hlo import parse_hlo_module
    from repro.core.optimize import default_candidates, greedy_search, \
        parse_stack
    from repro.launch.cell import build_cell
    from repro.launch.dryrun import mesh_topology
    from repro.launch.mesh import make_production_mesh
    # lazy: perf_report imports this module at top level (parse_value)
    from repro.launch.perf_report import build_scenario
    from repro.configs import registry as cfg_registry
    from repro import compat

    shape = cfg_registry.SHAPES[args.shape]
    multi = args.mesh == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    cost = CostModel(topo=mesh_topology(multi))
    with compat.set_mesh(mesh):
        cell = build_cell(cfg, shape, mesh)
        compiled = cell.lower().compile()
    module = parse_hlo_module(compiled.as_text())
    scenario, _ = build_scenario(module, cfg, cost,
                                 workers=args.cluster or 1,
                                 straggler=args.straggler)

    candidates = default_candidates(scenario)
    for spec in args.candidate:
        opt, over = parse_stack(spec)
        if over:
            raise SystemExit(f"--candidate {spec!r}: scenario overrides "
                             f"belong in --cluster/--straggler")
        candidates.append(opt)

    # rank by Amdahl-style headroom bounds first (repro.analysis): greedy
    # search then tries high-headroom candidates first, provably-hopeless
    # ones (bound <= 1x) are skipped, and the table shows why
    from repro.analysis import (format_opportunity_table, rank_opportunities,
                                searchable_candidates)
    opps = rank_opportunities(scenario, candidates, realize=True)
    print(format_opportunity_table(opps, title="what-if search ordering"))
    searchable = searchable_candidates(opps)
    skipped = [o for o in opps if o.skipped]
    if skipped:
        print(f"skipping {len(skipped)} candidate(s) whose bound proves no "
              f"improvement on this scenario")

    # the ranking already realized every candidate at depth 1: seed the
    # first greedy round with those predictions instead of re-simulating
    round1 = {id(o.optimization): o.prediction
              for o in opps if o.prediction is not None}
    best, trail = greedy_search(scenario, max_depth=args.search_whatif,
                                candidates=searchable, round1=round1)
    base = scenario.baseline().makespan
    print(f"baseline: {base*1e3:.3f} ms; searched {len(searchable)} of "
          f"{len(candidates)} registry candidates to depth "
          f"{args.search_whatif}")
    for i, pred in enumerate(trail):
        print(f"round {i+1}: {pred.optimization.spec():60s} "
              f"{pred.predicted*1e3:10.3f} ms  ({pred.speedup:.2f}x)")
    if best is None:
        print("no registered optimization improves this scenario")
    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
           "status": "ok", "mode": "whatif_search",
           "baseline_ms": base * 1e3,
           "best_stack": best.spec() if best is not None else None,
           "opportunities": [
               {"candidate": o.optimization.spec(),
                "bound": None if o.unbounded else o.bound,
                "cp_share": o.cp_share, "realized": o.realized,
                "skipped": o.skipped,
                "error": o.error or None} for o in opps],
           "trail": [{"stack": p.optimization.spec(),
                      "predicted_ms": p.predicted * 1e3,
                      "speedup": p.speedup} for p in trail]}
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {path}")


def main() -> None:
    from repro.configs import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--search-whatif", type=int, default=0,
                    help="greedy-search the optimization registry to this "
                         "stack depth instead of running the cell")
    ap.add_argument("--candidate", action="append", default=[],
                    help="extra search candidate as a registry spec, e.g. "
                         "dgc:compression=0.01 (repeatable)")
    ap.add_argument("--cluster", type=int, default=0,
                    help="search on the N-worker cluster route")
    ap.add_argument("--straggler", default="",
                    help="IDX:SLOWDOWN cluster straggler (with --cluster)")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if overrides:
        cfg = cfg.with_(**overrides)
    print(f"overrides: {overrides}")
    if args.search_whatif:
        search_whatif(args, cfg)
        return
    from repro.launch.dryrun import run_cell
    run_cell(args.arch, args.shape, args.mesh == "multi",
             out_dir=args.out, cfg_override=cfg, tag=args.tag)


if __name__ == "__main__":
    main()
