"""Build one (architecture x shape) dry-run cell: step fn + abstract args.

Everything here must run under ``jax.set_mesh(mesh)`` so the logical-axis
rules resolve against the target mesh.  No device memory is allocated —
inputs are ShapeDtypeStructs (the shannon/kernels pattern).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec
from repro.models.model import (ModelConfig, init_params, input_specs,
                                cache_spec, make_train_step, make_serve_step,
                                make_prefill_step)
from repro.models.paramdecl import (SpecLeaf, specs_of, shapes_of)
from repro.optim import AdamW
from repro.sharding import ShardingRules, DEFAULT_RULES


def _is_leaf(x):
    return isinstance(x, SpecLeaf)


def _ns_tree(tree, mesh, rules: ShardingRules):
    spec_tree = specs_of(tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class Cell:
    fn: Callable
    args: Tuple[Any, ...]             # ShapeDtypeStruct trees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.args)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               rules: Optional[ShardingRules] = None,
               optimizer: Optional[AdamW] = None) -> Cell:
    if rules is None:
        is_train = shape.kind == "train"
        layout = cfg.layout if is_train else cfg.serve_layout
        # adaptive resolution: pure-DP needs the batch to cover the whole
        # mesh (e.g. batch 256 on the 512-chip multi-pod mesh would leave
        # the model axis idle and replicate compute 16x) — degrade to the
        # weight-gather FSDP + TP layout instead.
        if layout == "dp" and shape.global_batch % mesh.devices.size != 0:
            layout = "v2"
            cfg = cfg.with_(**({"layout": "v2"} if is_train
                               else {"serve_layout": "v2"}))
        fsdp = True if is_train else cfg.serve_fsdp
        rules = ShardingRules(layout=layout, fsdp=fsdp)
    params_spec = init_params(cfg, None)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = optimizer or AdamW()
        state_spec = {"params": params_spec, "opt": opt.init(params_spec),
                      "step": SpecLeaf((), jnp.dtype(jnp.int32), ())}
        batch_spec = input_specs(cfg, kind="train", seq_len=shape.seq_len,
                                 global_batch=shape.global_batch)
        state_ns = _ns_tree(state_spec, mesh, rules)
        batch_ns = _ns_tree(batch_spec, mesh, rules)
        fn = make_train_step(cfg, opt)
        return Cell(
            fn=fn,
            args=(shapes_of(state_spec), shapes_of(batch_spec)),
            in_shardings=(state_ns, batch_ns),
            out_shardings=(state_ns, {"loss": rep, "grad_norm": rep}),
            donate_argnums=(0,),
        )

    if shape.kind == "prefill":
        batch_spec = input_specs(cfg, kind="prefill", seq_len=shape.seq_len,
                                 global_batch=shape.global_batch)
        cspec = cache_spec(cfg, shape.global_batch, shape.seq_len)
        params_ns = _ns_tree(params_spec, mesh, rules)
        batch_ns = _ns_tree(batch_spec, mesh, rules)
        cache_ns = _ns_tree(cspec, mesh, rules)
        tok_ns = _ns_tree(SpecLeaf((shape.global_batch, 1),
                                   jnp.dtype(jnp.int32), ("batch", None)),
                          mesh, rules)
        fn = make_prefill_step(cfg)
        return Cell(
            fn=fn,
            args=(shapes_of(params_spec), shapes_of(batch_spec)),
            in_shardings=(params_ns, batch_ns),
            out_shardings=(tok_ns, cache_ns),
            donate_argnums=(),
        )

    if shape.kind == "decode":
        cspec = cache_spec(cfg, shape.global_batch, shape.seq_len)
        tok_spec = SpecLeaf((shape.global_batch, 1), jnp.dtype(jnp.int32),
                            ("batch", None))
        pos_spec = SpecLeaf((), jnp.dtype(jnp.int32), ())
        params_ns = _ns_tree(params_spec, mesh, rules)
        cache_ns = _ns_tree(cspec, mesh, rules)
        tok_ns = _ns_tree(tok_spec, mesh, rules)
        fn = make_serve_step(cfg)
        return Cell(
            fn=fn,
            args=(shapes_of(params_spec), shapes_of(cspec),
                  shapes_of(tok_spec), shapes_of(pos_spec)),
            in_shardings=(params_ns, cache_ns, tok_ns, rep),
            out_shardings=(tok_ns, cache_ns),
            donate_argnums=(1,),
        )

    raise ValueError(f"unknown shape kind {shape.kind!r}")
