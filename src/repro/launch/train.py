"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128

``--smoke`` uses the arch's reduced config (CPU-runnable); otherwise the full
config (requires a real fleet; the dry-run path is ``repro.launch.dryrun``).
``--mesh local`` builds the largest mesh the local devices support.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM, make_batch, Prefetcher
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps))
    tc = TrainerConfig(steps=args.steps, log_every=args.log_every,
                       ckpt_dir=args.ckpt_dir)

    mesh = None
    if args.mesh == "local":
        from repro.launch.mesh import smoke_mesh
        mesh = smoke_mesh()

    def batches():
        step = 0
        while True:
            yield make_batch(cfg, seq_len=args.seq, batch=args.batch,
                             step=step)
            step += 1

    trainer = Trainer(cfg, tc, optimizer=opt, mesh=mesh)
    from repro import compat
    ctx = compat.set_mesh(mesh) if mesh is not None else _null()
    with ctx:
        trainer.fit(Prefetcher(batches()), steps=args.steps)
    first = trainer.metrics_log[0]["loss"]
    last = trainer.metrics_log[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(trainer.metrics_log, f)


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
