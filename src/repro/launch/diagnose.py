"""Diagnosis CLI: explain a captured step and rank what to try next.

Imports a per-worker profiler trace set (Chrome trace-event JSON / native
JSONL — :mod:`repro.traceio`), then runs the diagnosis subsystem
(:mod:`repro.analysis`) over it:

1. **fidelity** — the simulator's reproduction of the capture, diffed
   task-by-task (per-kind error rollups, top-K mispredicted tasks): how
   much to trust the what-ifs below;
2. **critical path** — the makespan-defining chain of the (re)simulated
   step, attributed into compute / comm / host / idle per worker: where
   the time actually goes;
3. **opportunity ranking** — Amdahl-style speedup upper bounds for every
   registered optimization, bound vs realized: what is worth trying first;
4. optionally ``--calibrate``: fit the CostModel constants to the capture
   (:mod:`repro.analysis.calibrate`) and print the before/after fidelity
   table — the what-ifs then run on the calibrated model;
5. optionally a concrete ``--what-if`` stack, reported with its own
   critical path so before/after chains can be compared.

    PYTHONPATH=src python -m repro.launch.diagnose --trace-dir traces/ \\
        [--calibrate] [--what-if 'amp,bandwidth:factor=2'] [--top 10] \\
        [--no-rank]
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(
        description="diagnose a captured per-worker trace set: "
                    "prediction fidelity, critical path, ranked what-ifs")
    ap.add_argument("--trace-dir", required=True, dest="trace_dir",
                    help="directory with one trace file per worker "
                         "(worker0.jsonl / worker0.trace.json, ...)")
    ap.add_argument("--what-if", default="", dest="what_if",
                    help="registry stack to evaluate on top of the "
                         "diagnosis, e.g. 'amp,bandwidth:factor=2'")
    ap.add_argument("--top", type=int, default=10,
                    help="entries in the top-mispredicted and "
                         "longest-segment lists (default 10)")
    ap.add_argument("--no-diff", action="store_true",
                    help="skip the predicted-vs-captured diff")
    ap.add_argument("--no-rank", action="store_true",
                    help="skip the opportunity ranking")
    ap.add_argument("--straggler", default="",
                    help="IDX:SLOWDOWN what-if worker spec layered on top "
                         "of the traced speeds")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the CostModel to the capture first "
                         "(repro.analysis.calibrate) and print the "
                         "before/after fidelity table; the diagnosis "
                         "below then runs on the calibrated model")
    ap.add_argument("--timeline", action="store_true",
                    help="print counter-timeline rollups (per-worker "
                         "utilization, peak live memory, ready-queue "
                         "depth, COMM bytes in flight — repro.obs) next "
                         "to the critical path")
    ap.add_argument("--telemetry", default="",
                    help="append the tool's own span telemetry (import, "
                         "build, calibrate timings) as JSONL to this "
                         "path (repro.obs.spans; same as "
                         "REPRO_TELEMETRY=<path>)")
    args = ap.parse_args()

    if args.telemetry:
        from repro import obs
        obs.configure(args.telemetry)

    from repro.analysis import (diff_prediction, format_opportunity_table,
                                rank_opportunities)
    from repro.launch.perf_report import (format_cluster_report,
                                          load_trace_scenario)

    imp, scenario = load_trace_scenario(args.trace_dir, args.straggler)
    n = imp.num_workers
    if args.calibrate:
        scenario, report = scenario.calibrate()
        print(report.format())
    pred, tf, cg = scenario.evaluate("noop")

    if not args.no_diff:
        diff = diff_prediction(pred, tf, cg, imp)
        print(diff.format(top=args.top))
    print(pred.critical_path.format(top=args.top))
    if args.timeline:
        from repro.obs import format_timeline_report
        print(format_timeline_report(pred.timelines))
    print(format_cluster_report(pred.cluster,
                                title=f"imported cluster x{n}"))

    if not args.no_rank:
        opps = rank_opportunities(scenario, realize=True,
                                  baseline_cluster=cg)
        print(format_opportunity_table(opps))

    if args.what_if:
        wpred = scenario.predict(args.what_if)
        print(f"== what-if {args.what_if} ==")
        print(f"baseline  : {wpred.baseline * 1e3:10.3f} ms")
        print(f"predicted : {wpred.predicted * 1e3:10.3f} ms "
              f"({wpred.speedup:.2f}x)")
        print(wpred.critical_path.format(top=args.top))
        if args.timeline:
            from repro.obs import format_timeline_report
            print(format_timeline_report(wpred.timelines))


if __name__ == "__main__":
    main()
