"""Serving launcher: batched greedy generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serve import ServeEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             args.prompt_len)),
                    max_new_tokens=args.max_new)
            for _ in range(args.batch)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batch={args.batch})")
    for i, r in enumerate(results[:2]):
        print(f"  req{i}: {r.tokens[:12]}...")


if __name__ == "__main__":
    main()
