"""Goodput-under-failures CLI: useful steps/hour for a fault-policy stack.

Builds a :class:`repro.faults.FaultScenario` — from a synthetic
data-parallel step by default, or from imported per-worker profiler traces
with ``--trace-dir`` — and prints the goodput table for the baseline stack
plus every requested what-if::

    PYTHONPATH=src python -m repro.launch.goodput --workers 16 \\
        --mtbf-hours 6 --what-if 'ddp,elastic' \\
        --what-if 'ddp,hot_spare:count=2'

``--what-if`` repeats; each spec is any registry stack mixing fault
policies (``ckpt_interval:steps=K``, ``elastic``, ``hot_spare``,
``straggler_mitigation``) with ordinary graph what-ifs (``amp``,
``bandwidth``, ...).  ``--sweep-interval`` sweeps the checkpoint interval
around the Young/Daly closed-form optimum and marks both.
"""

import argparse
import json
import math
import sys

from repro.core import parse_stack
from repro.faults import (FaultScenario, demo_scenario, format_goodput_table,
                          young_daly_interval)


def build_scenario(args) -> FaultScenario:
    kw = dict(mtbf_s=args.mtbf_hours * 3600.0, horizon_s=args.horizon_s,
              seed=args.seed, ckpt_interval_steps=args.ckpt_interval,
              preempt_period_s=args.preempt_period,
              preempt_duration_s=args.preempt_duration,
              straggler_rate_per_hour=args.straggler_rate,
              straggler_slowdown=args.straggler_slowdown)
    if args.trace_dir:
        from repro.launch.perf_report import load_trace_scenario
        _, scn = load_trace_scenario(args.trace_dir)
        return FaultScenario(graph=scn.graph, cost=scn.cost,
                             layer_grad_bytes=scn.layer_grad_bytes,
                             workers=scn.workers, traces=scn.traces, **kw)
    return demo_scenario(workers=args.workers, layers=args.layers, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="goodput under failures: useful steps/hour, "
                    "availability and lost work for fault-policy what-ifs "
                    "over the dependency-graph simulator")
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--layers", type=int, default=8,
                    help="synthetic step graph depth")
    ap.add_argument("--mtbf-hours", type=float, default=6.0,
                    help="per-worker MTBF in hours (0 = no failures)")
    ap.add_argument("--horizon-s", type=float, default=86400.0,
                    help="simulated wall-clock, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-interval", type=int, default=100,
                    help="baseline checkpoint interval, steps")
    ap.add_argument("--preempt-period", type=float, default=0.0,
                    help="preemption window period, seconds (0 = none)")
    ap.add_argument("--preempt-duration", type=float, default=0.0)
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="transient straggler windows per hour (0 = none)")
    ap.add_argument("--straggler-slowdown", type=float, default=2.0)
    ap.add_argument("--trace-dir", default=None,
                    help="build the training side from imported per-worker "
                         "profiler traces instead of the synthetic step")
    ap.add_argument("--base", default="ddp",
                    help="baseline training stack the fault policies ride "
                         "on (synthetic route; 'noop' for traces that "
                         "already carry collectives)")
    ap.add_argument("--what-if", action="append", default=[],
                    help="registry stack spec; repeatable")
    ap.add_argument("--sweep-interval", action="store_true",
                    help="sweep the checkpoint interval around the "
                         "Young/Daly optimum")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)
    if args.trace_dir and args.base == "ddp":
        args.base = "noop"      # traces already carry their collectives

    scn = build_scenario(args)
    rec = scn.recovery
    print(f"# {scn.num_workers} workers, per-worker MTBF "
          f"{args.mtbf_hours:.1f}h (job "
          f"{scn.job_mtbf_s / 3600.0 if scn.mtbf_s else math.inf:.2f}h), "
          f"horizon {scn.horizon_s / 3600.0:.1f}h, ckpt every "
          f"{scn.ckpt_interval_steps} steps; recovery: {rec.describe()}",
          file=sys.stderr)

    preds = [scn.predict(args.base)]
    for spec in args.what_if:
        opt, overrides = parse_stack(spec)
        if overrides:
            raise SystemExit(f"scenario overrides {sorted(overrides)} are "
                             f"not supported in --what-if specs here")
        preds.append(scn.predict(opt))

    if args.as_json:
        out = []
        for p in preds:
            r = p.report
            out.append({"spec": p.optimization.spec(),
                        "goodput_steps_per_hour": r.goodput_steps_per_hour,
                        "goodput_fraction": r.goodput_fraction,
                        "availability": r.availability,
                        "failures": r.failures,
                        "lost_steps": r.lost_steps,
                        "useful_steps": r.useful_steps,
                        "speedup": p.speedup})
        print(json.dumps(out, indent=2))
    else:
        print(format_goodput_table(preds))

    if args.sweep_interval:
        best, points, k_yd = scn.optimal_ckpt_interval(args.base)
        tau = young_daly_interval(rec.checkpoint_write_s, scn.job_mtbf_s)
        print(f"\n== checkpoint-interval sweep (Young/Daly optimum "
              f"{tau:.0f}s ~= {k_yd} steps) ==")
        for p in points:
            k = p.policy.ckpt_interval_steps
            mark = "  <- best" if p is best else \
                ("  <- Young/Daly" if k == k_yd else "")
            print(f"  every {k:>6d} steps: "
                  f"{p.report.goodput_steps_per_hour:>10,.0f} useful "
                  f"steps/h ({p.report.goodput_fraction:.1%}){mark}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
