"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
import; everything else sees the 1 real CPU device.

Mesh layout:
  single-pod : (16, 16)     axes ("data", "model")          = 256 chips
  multi-pod  : (2, 16, 16)  axes ("pod", "data", "model")   = 512 chips

``pod`` is pure data parallelism over the slow cross-pod links by default
(the collective cost model quantifies why; see EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Mesh over the first prod(shape) local devices (supports building the
    256-chip mesh inside the 512-device dry-run process)."""
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {tuple(shape)} needs {n} devices, have {len(devs)} "
            f"(dry-run requires XLA_FLAGS=--xla_force_host_platform_device_count)")
    from repro import compat
    return compat.make_mesh(
        tuple(shape), tuple(axes),
        devices=devs[:n] if len(devs) != n else None)


def smoke_mesh(model: int = 2, data: Optional[int] = None):
    """Largest (data, model) mesh the *local* device set supports (tests)."""
    n = len(jax.devices())
    model = min(model, n)
    data = data or max(1, n // model)
    return make_mesh((data, model), ("data", "model"))


def devices_per_pod(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.axis_shape
                     if hasattr(mesh, "axis_shape") else mesh.devices.shape))
    pods = sizes.get("pod", 1)
    total = 1
    for s in (mesh.devices.shape if hasattr(mesh, "devices") else []):
        total *= s
    return total // pods if pods else total
