"""Calibration CLI: fit the CostModel to a captured trace set.

Closes the fidelity loop (ROADMAP item 1, dPRO arXiv:2205.02473): imports
a per-worker profiler capture — native JSONL, Chrome trace-event JSON, or
a real ``jax.profiler`` logdir (``plugins/profile/<run>/*.trace.json.gz``,
see :mod:`repro.traceio.xla`) — then iterates simulate → diff → refit
through the real simulator (:mod:`repro.analysis.calibrate`) and prints
the before/after fidelity table: per-kind WAPE, makespan error, and every
constant the fit moved.

    PYTHONPATH=src python -m repro.launch.calibrate --trace-dir traces/ \\
        [--max-rounds 6] [--tol 1e-3] [--constants kind_scale:compute,...]\\
        [--diff] [--strict-align]

The calibrated constants print in ``CostModel.with_constants`` form so a
follow-up what-if run can reuse them.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fit CostModel constants to a captured trace set and "
                    "report fidelity before/after")
    ap.add_argument("--trace-dir", required=True, dest="trace_dir",
                    help="per-worker trace directory (worker*.jsonl / "
                         "*.trace.json) or a jax.profiler logdir")
    ap.add_argument("--max-rounds", type=int, default=6, dest="max_rounds",
                    help="coordinate-descent rounds (default 6)")
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="relative per-round loss improvement below which "
                         "the fit stops (default 1e-3)")
    ap.add_argument("--constants", default="",
                    help="comma-separated subset of fittable constants, "
                         "e.g. 'kind_scale:compute,ici_factor' "
                         "(default: all the capture can inform)")
    ap.add_argument("--diff", action="store_true",
                    help="also print the full post-calibration diff "
                         "(top mispredicted tasks)")
    ap.add_argument("--strict-align", action="store_true",
                    dest="strict_align",
                    help="raise instead of warn when the capture's clocks "
                         "cannot be reliably aligned")
    ap.add_argument("--straggler", default="",
                    help="IDX:SLOWDOWN what-if worker spec layered on top "
                         "of the traced speeds")
    args = ap.parse_args()

    from repro import traceio
    from repro.launch.perf_report import load_trace_scenario

    if args.strict_align:
        # fail fast, before the scenario import prints anything
        traceio.load_trace_dir(args.trace_dir, align="strict")
    imp, scenario = load_trace_scenario(args.trace_dir, args.straggler)
    constants = [c.strip() for c in args.constants.split(",") if c.strip()] \
        or None
    calibrated, report = scenario.calibrate(
        constants=constants, max_rounds=args.max_rounds, tol=args.tol)
    print(report.format())
    if args.diff:
        print(report.after.format())
    moved = {n: v[1] for n, v in report.fitted.items()
             if v[0] != v[1]}
    if moved:
        print(f"reuse with: CostModel().with_constants({moved!r})")


if __name__ == "__main__":
    main()
