"""Serving what-if CLI: predict latency/goodput for a policy stack.

Generates a seeded open-loop workload, builds a
:class:`repro.serving.ServingScenario` priced by the arch's registered
:func:`repro.configs.serving_cost`, and prints the latency/goodput table
for the baseline (static slots, seed-engine semantics) plus every
requested what-if stack — all through the simulator, nothing is served::

    PYTHONPATH=src python -m repro.launch.serve_sim --model llama3_405b \\
        --rate 500 --duration 60 --what-if 'continuous_batching,tp:degree=8'

``--what-if`` repeats and each spec is any registry stack
(``continuous_batching,chunked_prefill:chunk=256,tp:degree=8``); add
``--bound`` to print each stack's headroom upper bound next to the
realized speedup.  ``--trace`` replays a JSONL request log instead of the
Poisson process.
"""

import argparse
import json
import sys

from repro.configs import normalize_arch, serving_cost
from repro.core import parse_stack
from repro.serving import (ServingPolicy, ServingScenario,
                           format_serving_table, poisson_workload,
                           trace_workload)


def build_scenario(args) -> ServingScenario:
    cost = serving_cost(args.model, smoke=args.smoke)
    if args.trace:
        wl = trace_workload(args.trace)
    else:
        wl = poisson_workload(args.rate, args.duration, seed=args.seed,
                              prompt_mean=args.prompt_mean,
                              output_mean=args.output_mean)
    policy = ServingPolicy(mode="static", slots=args.slots)
    return ServingScenario(workload=wl, policy=policy, serving_cost=cost)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="request-level serving simulation: p50/p99 latency and "
                    "goodput what-ifs over the dependency-graph simulator")
    ap.add_argument("--model", default="llama3_405b",
                    help="arch id (dashed or underscore form)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="arrival-window length, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-mean", type=int, default=512)
    ap.add_argument("--output-mean", type=int, default=128)
    ap.add_argument("--slots", type=int, default=8,
                    help="baseline policy's batch slots")
    ap.add_argument("--smoke", action="store_true",
                    help="price the reduced smoke config")
    ap.add_argument("--trace", default=None,
                    help="JSONL request log replayed instead of Poisson")
    ap.add_argument("--what-if", action="append", default=[],
                    help="registry stack spec; repeatable")
    ap.add_argument("--bound", action="store_true",
                    help="print each stack's headroom upper bound")
    ap.add_argument("--json", dest="as_json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)
    args.model = normalize_arch(args.model)

    scn = build_scenario(args)
    wl = scn.workload
    print(f"# {args.model}: {len(wl)} requests over {wl.duration:.1f}s "
          f"({wl.offered_rate():.1f} req/s offered, "
          f"{wl.total_output_tokens} output tokens), baseline "
          f"static_slots:{scn.policy.slots}", file=sys.stderr)

    preds = [scn.predict("noop")]
    for spec in args.what_if:
        opt, overrides = parse_stack(spec)
        if overrides:
            raise SystemExit(f"serving stacks take no scenario overrides, "
                             f"got {overrides} in {spec!r}")
        preds.append(scn.predict(opt))

    if args.as_json:
        out = []
        for p in preds:
            out.append({
                "spec": p.optimization.spec(), "speedup": p.speedup,
                "makespan": p.predicted, "goodput": p.goodput,
                "ttft_p50": p.ttft_p50, "ttft_p99": p.ttft_p99,
                "tpot_p50": p.tpot_p50, "tpot_p99": p.tpot_p99,
                "latency_p50": p.latency_p50, "latency_p99": p.latency_p99,
                "tokens_generated": p.tokens_generated,
                "requests_completed": p.requests_completed,
            })
        print(json.dumps(out, indent=2))
    else:
        print(format_serving_table(preds))
    if args.bound:
        from repro.analysis.opportunity import opportunity_bound
        for p in preds[1:]:
            b = opportunity_bound(scn, p.optimization)
            print(f"bound {p.optimization.spec()}: <= {b:.2f}x "
                  f"(realized {p.speedup:.2f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
