import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Cell inspector: rank the collective / memory hot spots of one dry-run cell.

The §Perf loop's "profile" (DESIGN.md: the profile is the lowered IR +
cost_analysis, not a wall-clock trace):

    PYTHONPATH=src python -m repro.launch.inspect --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--top 25] [--kind collective|memory]
"""

import argparse
import collections

import jax

from repro.configs import registry
from repro.core.costmodel import CostModel
from repro.core.hlo import parse_hlo_module, _CostVisitor, COLLECTIVE_OPS
from repro.launch.mesh import make_production_mesh
from repro.launch.cell import build_cell
from repro.launch.dryrun import mesh_topology, DEVICES_PER_POD
from repro.sharding import ShardingRules


def rank_cell(arch: str, shape_name: str, multi_pod: bool = False,
              kind: str = "collective", top: int = 25, layout: str = "v2"):
    cfg = registry.get_config(arch)
    shape = registry.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cost = CostModel(topo=mesh_topology(multi_pod))
    from repro import compat
    with compat.set_mesh(mesh):
        cell = build_cell(cfg, shape, mesh,
                          ShardingRules(layout=layout))
        compiled = cell.lower().compile()
    module = parse_hlo_module(compiled.as_text())
    vis = _CostVisitor(module, cost, DEVICES_PER_POD)
    rows = []

    def walk(comp, mult, depth=0):
        c = module.computations.get(comp)
        if c is None or depth > 24:
            return
        types = {i.name: i.type_str for i in c.instrs}
        for i in c.instrs:
            if i.opcode == "while":
                n = i.trip_count() or 1
                for b in i.called():
                    walk(b, mult * n, depth + 1)
                continue
            if i.opcode in ("call", "async-start"):
                for b in i.called():
                    walk(b, mult, depth + 1)
                continue
            if i.opcode == "conditional":
                br = i.branches() or i.called()
                if br:
                    walk(br[0], mult, depth + 1)
                continue
            d = vis.classify(i, types)
            if d is None:
                continue
            is_coll = i.opcode.replace("-start", "") in COLLECTIVE_OPS
            if kind == "collective" and not is_coll:
                continue
            if kind == "memory" and is_coll:
                continue
            metric = d.get("comm_bytes", 0.0) if kind == "collective" \
                else d["bytes"]
            rows.append((mult * metric, i.opcode, mult,
                         (i.op_name or i.name)[:110]))

    walk(module.entry, 1.0)
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"{arch} x {shape_name} x "
          f"{'multi' if multi_pod else 'single'} [{kind}] "
          f"total={total/1e9:.2f} GB/device")
    agg = collections.Counter()
    for b, op, m, name in rows:
        agg[op] += b
    print({k: f"{v/1e9:.2f}GB" for k, v in agg.most_common()})
    for b, op, m, name in rows[:top]:
        print(f"{b/1e6:10.1f}MB x{m:5.0f} {op:20s} {name}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--kind", default="collective",
                    choices=["collective", "memory"])
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--layout", default="v2")
    args = ap.parse_args()
    rank_cell(args.arch, args.shape, args.multi_pod, args.kind, args.top,
              args.layout)


if __name__ == "__main__":
    main()
