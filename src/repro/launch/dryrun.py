import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Everything
is ShapeDtypeStruct-based: no tensor is ever allocated.

Per cell, this driver records:
  * ``compiled.memory_analysis()``  — bytes/device (proves it fits / honest OOM)
  * ``compiled.cost_analysis()``    — XLA FLOPs/bytes
  * trip-count-aware FLOPs/bytes/collective bytes from the parsed HLO
  * the three §Roofline terms + dominant bound + useful-compute ratio

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import registry
from repro.core.costmodel import CostModel, MeshTopology
from repro.core.hlo import parse_hlo_module, aggregate_costs
from repro.core.roofline import roofline_report, format_row
from repro.launch.mesh import make_production_mesh
from repro.launch.cell import build_cell
from repro.models.model import active_params
from repro.sharding import ShardingRules

DEVICES_PER_POD = 256


def mesh_topology(multi_pod: bool) -> MeshTopology:
    return (MeshTopology.multi_pod(2, 16, 16) if multi_pod
            else MeshTopology.single_pod(16, 16))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, rules: ShardingRules = None,
             cfg_override=None, tag: str = "") -> dict:
    cfg = cfg_override or registry.get_config(arch)
    shape = registry.SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    ok, reason = registry.runnable(arch, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        _emit(rec, out_dir, tag)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    topo = mesh_topology(multi_pod)
    cost = CostModel(topo=topo)
    try:
        from repro import compat
        with compat.set_mesh(mesh):
            cell = build_cell(cfg, shape, mesh, rules)
            lowered = cell.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            from repro.compat import cost_analysis_dict
            xla_cost = cost_analysis_dict(compiled)
            module = parse_hlo_module(compiled.as_text())
            agg = aggregate_costs(module, cost,
                                  devices_per_pod=DEVICES_PER_POD)
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "FAILED", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        _emit(rec, out_dir, tag)
        return rec

    chips = 512 if multi_pod else 256
    rep = roofline_report(
        agg, chips=chips, kind=shape.kind,
        n_active_params=active_params(cfg), seq_len=shape.seq_len,
        global_batch=shape.global_batch, xla_cost=xla_cost,
        memory_stats=mem)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "kind": shape.kind,
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
           "roofline": rep}
    _emit(rec, out_dir, tag)
    return rec


def _emit(rec: dict, out_dir: Optional[str], tag: str = "") -> None:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        sfx = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{sfx}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    if rec["status"] == "ok":
        print(format_row(rec["arch"], rec["shape"], rec["mesh"],
                         rec["roofline"]), flush=True)
        ma = rec["roofline"]
        print(f"    bytes/dev: args={ma.get('mem_argument_size_in_bytes', 0)/1e9:.2f}GB "
              f"temp={ma.get('mem_temp_size_in_bytes', 0)/1e9:.2f}GB "
              f"fits_hbm={ma.get('fits_hbm')} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s",
              flush=True)
    else:
        print(f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
              f"{rec['status']}: {rec.get('reason') or rec.get('error')}",
              flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-skipped", action="store_true")
    args = ap.parse_args()

    archs = registry.list_archs() if args.arch in ("all", "") \
        else args.arch.split(",")
    shapes = list(registry.SHAPES) if args.shape in ("all", "") \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, args.out)
                if rec["status"] == "FAILED":
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")
    print("dry-run complete: all cells lowered+compiled.")


if __name__ == "__main__":
    main()
