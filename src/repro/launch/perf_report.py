import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Daydream-modeled kernel substitution: flash attention (§Perf, paper §7.4).

The compiled dry-run artifact shows pure-XLA attention materializing the
score chain through HBM every kv-chunk (the dominant memory term at train
shapes).  The Pallas flash kernel (kernels/flash_attention.py — validated
against its oracle in interpret mode) keeps score tiles in VMEM, so its HBM
traffic is just q/k/v/o.  Pallas cannot lower into the CPU-hosted TPU dry-run
artifact, so — exactly the paper's workflow for new kernels (§7.4: "profile
the kernel separately, input the result into Daydream") — this report:

  1. compiles the cell and walks the HLO, separating attention-inner-loop
     bytes from everything else;
  2. replaces them with the kernel's analytic traffic (q+k+v+o per pass);
  3. re-derives the roofline terms, tagged ``modeled_flash``.

    PYTHONPATH=src python -m repro.launch.perf_report --arch tinyllama-1.1b \
        --shape train_4k --set layout=dp --tag iter4_flash
"""

import argparse
import json
import re

import jax

from repro.configs import registry
from repro.core.costmodel import CostModel
from repro.core.hlo import parse_hlo_module, _CostVisitor, COLLECTIVE_OPS
from repro.core.roofline import roofline_report, format_row
from repro.core.task import TaskKind
from repro.launch.mesh import make_production_mesh
from repro.launch.cell import build_cell
from repro.launch.dryrun import mesh_topology, DEVICES_PER_POD
from repro.launch.hillclimb import parse_value
from repro.models.model import active_params
from repro.sharding import ShardingRules

_ATTN_SCOPE = re.compile(r"/attn/")


def aggregate_with_attention_split(module, cost):
    """Trip-count-aware totals + the attention-inner-while slice."""
    vis = _CostVisitor(module, cost, DEVICES_PER_POD)
    tot = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
           "collective_s": 0.0, "attn_bytes": 0.0, "attn_flops": 0.0}

    def walk(comp, mult, in_attn, depth=0):
        c = module.computations.get(comp)
        if c is None or depth > 24:
            return
        types = {i.name: i.type_str for i in c.instrs}
        for i in c.instrs:
            if i.opcode == "while":
                n = i.trip_count() or 1
                inner = in_attn or bool(_ATTN_SCOPE.search(i.op_name or ""))
                for b in i.called():
                    walk(b, mult * n, inner, depth + 1)
                continue
            if i.opcode in ("call", "async-start"):
                for b in i.called():
                    walk(b, mult, in_attn, depth + 1)
                continue
            if i.opcode == "conditional":
                br = i.branches() or i.called()
                if br:
                    walk(br[0], mult, in_attn, depth + 1)
                continue
            d = vis.classify(i, types)
            if d is None:
                continue
            tot["flops"] += mult * d["flops"]
            tot["bytes"] += mult * d["bytes"]
            if d["kind"] == TaskKind.COLLECTIVE:
                tot["collective_bytes"] += mult * d["comm_bytes"]
                tot["collective_s"] += mult * d["duration"]
            elif in_attn or _ATTN_SCOPE.search(i.op_name or ""):
                tot["attn_bytes"] += mult * d["bytes"]
                tot["attn_flops"] += mult * d["flops"]

    walk(module.entry, 1.0, False)
    return tot


def flash_traffic(cfg, shape, chips: int) -> float:
    """Per-device HBM bytes of the flash kernel across the step.

    fwd + bwd-recompute + bwd = 3 kernel passes (bwd reads dO too: 4th
    tensor stream folded into the factor), each streaming q, k, v, o once.
    Train shapes double for the gradient outputs.
    """
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.head_dim or cfg.d_model // max(cfg.n_heads, 1)
    per_pass = 4 * B * S * cfg.n_heads * hd * 2          # q,k,v,o bf16
    passes = 3.0 if shape.kind == "train" else 1.0
    layers = cfg.n_layers
    return passes * layers * per_pass / chips


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="modeled_flash")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    for kv in args.set:
        k, v = kv.split("=", 1)
        cfg = cfg.with_(**{k: parse_value(v)})
    shape = registry.SHAPES[args.shape]
    multi = args.mesh == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    cost = CostModel(topo=mesh_topology(multi))
    with jax.set_mesh(mesh):
        cell = build_cell(cfg, shape, mesh)
        compiled = cell.lower().compile()
    module = parse_hlo_module(compiled.as_text())
    tot = aggregate_with_attention_split(module, cost)

    fb = flash_traffic(cfg, shape, chips)
    agg = {"flops": tot["flops"],
           "bytes": tot["bytes"] - tot["attn_bytes"] + fb,
           "collective_bytes": tot["collective_bytes"],
           "collective_s": tot["collective_s"]}
    base_agg = {"flops": tot["flops"], "bytes": tot["bytes"],
                "collective_bytes": tot["collective_bytes"],
                "collective_s": tot["collective_s"]}
    kw = dict(chips=chips, kind=shape.kind,
              n_active_params=active_params(cfg), seq_len=shape.seq_len,
              global_batch=shape.global_batch)
    base = roofline_report(base_agg, **kw)
    modeled = roofline_report(agg, **kw)
    print("compiled    :", format_row(args.arch, args.shape, args.mesh, base))
    print("with flash  :", format_row(args.arch, args.shape, args.mesh,
                                      modeled))
    print(f"attention-loop bytes replaced: {tot['attn_bytes']/1e9:.1f} GB "
          f"-> flash kernel {fb/1e9:.2f} GB per device")
    os.makedirs(args.out, exist_ok=True)
    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
           "status": "ok", "modeled": "flash_attention_substitution",
           "attn_bytes_removed": tot["attn_bytes"],
           "flash_bytes_added": fb,
           "roofline_compiled": base, "roofline": modeled}
    with open(os.path.join(
            args.out,
            f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"),
            "w") as f:
        json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
