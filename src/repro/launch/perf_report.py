import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Daydream-modeled kernel substitution: flash attention (§Perf, paper §7.4).

The compiled dry-run artifact shows pure-XLA attention materializing the
score chain through HBM every kv-chunk (the dominant memory term at train
shapes).  The Pallas flash kernel (kernels/flash_attention.py — validated
against its oracle in interpret mode) keeps score tiles in VMEM, so its HBM
traffic is just q/k/v/o.  Pallas cannot lower into the CPU-hosted TPU dry-run
artifact, so — exactly the paper's workflow for new kernels (§7.4: "profile
the kernel separately, input the result into Daydream") — this report:

  1. compiles the cell and walks the HLO, separating attention-inner-loop
     bytes from everything else;
  2. replaces them with the kernel's analytic traffic (q+k+v+o per pass);
  3. re-derives the roofline terms, tagged ``modeled_flash``.

    PYTHONPATH=src python -m repro.launch.perf_report --arch tinyllama-1.1b \
        --shape train_4k --set layout=dp --tag iter4_flash

Trace-import route (no compile; see repro.traceio): import real per-worker
profiler traces, run a registry stack on the asymmetric imported cluster,
and export the prediction for Perfetto:

    PYTHONPATH=src python -m repro.launch.perf_report --trace-dir traces/ \
        --what-if 'amp,bandwidth:factor=2' --export-trace predicted/
"""

import argparse
import json
import re

import jax

from repro.configs import registry
from repro.core.cluster import ClusterResult, WorkerSpec
from repro.core.costmodel import CostModel
from repro.core.hlo import parse_hlo_module, extract_graph, _CostVisitor, COLLECTIVE_OPS
from repro.core.roofline import roofline_report, format_row
from repro.core.task import TaskKind
from repro.launch.mesh import make_production_mesh
from repro.launch.cell import build_cell
from repro.launch.dryrun import mesh_topology, DEVICES_PER_POD
from repro.launch.hillclimb import parse_value
from repro.models.model import active_params
from repro.sharding import ShardingRules

_ATTN_SCOPE = re.compile(r"/attn/")


def aggregate_with_attention_split(module, cost):
    """Trip-count-aware totals + the attention-inner-while slice."""
    vis = _CostVisitor(module, cost, DEVICES_PER_POD)
    tot = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
           "collective_s": 0.0, "attn_bytes": 0.0, "attn_flops": 0.0}

    def walk(comp, mult, in_attn, depth=0):
        c = module.computations.get(comp)
        if c is None or depth > 24:
            return
        types = {i.name: i.type_str for i in c.instrs}
        for i in c.instrs:
            if i.opcode == "while":
                n = i.trip_count() or 1
                inner = in_attn or bool(_ATTN_SCOPE.search(i.op_name or ""))
                for b in i.called():
                    walk(b, mult * n, inner, depth + 1)
                continue
            if i.opcode in ("call", "async-start"):
                for b in i.called():
                    walk(b, mult, in_attn, depth + 1)
                continue
            if i.opcode == "conditional":
                br = i.branches() or i.called()
                if br:
                    walk(br[0], mult, in_attn, depth + 1)
                continue
            d = vis.classify(i, types)
            if d is None:
                continue
            tot["flops"] += mult * d["flops"]
            tot["bytes"] += mult * d["bytes"]
            if d["kind"] == TaskKind.COLLECTIVE:
                tot["collective_bytes"] += mult * d["comm_bytes"]
                tot["collective_s"] += mult * d["duration"]
            elif in_attn or _ATTN_SCOPE.search(i.op_name or ""):
                tot["attn_bytes"] += mult * d["bytes"]
                tot["attn_flops"] += mult * d["flops"]

    walk(module.entry, 1.0, False)
    return tot


def flash_traffic(cfg, shape, chips: int) -> float:
    """Per-device HBM bytes of the flash kernel across the step.

    fwd + bwd-recompute + bwd = 3 kernel passes (bwd reads dO too: 4th
    tensor stream folded into the factor), each streaming q, k, v, o once.
    Train shapes double for the gradient outputs.
    """
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.head_dim or cfg.d_model // max(cfg.n_heads, 1)
    per_pass = 4 * B * S * cfg.n_heads * hd * 2          # q,k,v,o bf16
    passes = 3.0 if shape.kind == "train" else 1.0
    layers = cfg.n_layers
    return passes * layers * per_pass / chips


def format_cluster_report(result: ClusterResult, *, title: str = "cluster",
                          unit: float = 1e3) -> str:
    """Per-worker table for a :class:`ClusterResult` (unit=1e3 -> ms).

    One row per worker: local makespan, device/comm/host busy time, idle
    time, and the slowdown vs the fastest worker — the straggler / skew
    signal the single-graph what-if path cannot produce.
    """
    best = min((r.makespan for r in result.per_worker.values()),
               default=0.0) or 1.0
    lines = [f"== {title}: {len(result.workers)} workers, "
             f"global makespan {result.makespan * unit:.3f} ==",
             "worker  makespan   device     comm      host      idle    vs-best"]
    for i in sorted(result.per_worker):
        r = result.per_worker[i]
        dev = r.thread_busy.get("device", 0.0)
        host = r.thread_busy.get("host", 0.0)
        comm = sum(v for k, v in r.thread_busy.items()
                   if k not in ("device", "host", "data"))
        idle = r.breakdown.get("idle_s", 0.0)
        lines.append(f"w{i:<5d}  {r.makespan * unit:8.3f}  {dev * unit:8.3f} "
                     f"{comm * unit:8.3f}  {host * unit:8.3f}  "
                     f"{idle * unit:8.3f}   {r.makespan / best:5.2f}x")
    return "\n".join(lines)


def _parse_straggler(straggler: str, workers: int):
    try:
        idx_s, slow_s = straggler.split(":")
        idx, slow = int(idx_s), float(slow_s)
    except ValueError:
        raise SystemExit(
            f"--straggler expects IDX:SLOWDOWN (e.g. 0:2.0), "
            f"got {straggler!r}")
    if not 0 <= idx < workers:
        raise SystemExit(
            f"--straggler index {idx} out of range for {workers} workers")
    return idx, slow


def build_scenario(module, cfg, cost, *, workers=1, straggler: str = ""):
    """Extract the compiled step's graph into an optimize.Scenario.

    Gradient buckets are keyed by the layer tags that actually appear on the
    graph's backward tasks so the all-reduce legs gate on real backprop
    (wait-free-backprop wiring); total payload is the config's parameter
    bytes.  If the trace carries no layer tags (fully scanned/fused module),
    the fallback is one synthetic bucket list — cluster reports then show
    per-worker compute/comm splits but no backprop-overlap coupling.

    ``workers``: 1 keeps the analytical single-graph route; an int > 1 (or
    a ``--straggler`` spec) builds a WorkerSpec list so predictions route
    through the global ClusterGraph.
    """
    from repro.core.optimize import Scenario
    title = ""
    if isinstance(workers, int) and workers > 1:
        specs = [WorkerSpec() for _ in range(workers)]
        title = f"cluster x{workers}"
        if straggler:
            idx, slow = _parse_straggler(straggler, workers)
            specs[idx] = WorkerSpec(compute_scale=slow)
            title += f" (w{idx} {slow}x slower)"
        workers = specs
    graph = extract_graph(module, cost)
    layers = sorted({t.layer for t in graph.tasks()
                     if t.layer and t.phase == "bwd"})
    if not layers:
        layers = [f"layer{i}" for i in range(max(1, cfg.n_layers))]
    per_layer = 2.0 * active_params(cfg) / len(layers)  # bf16 grads
    grads = {l: per_layer for l in layers}
    return Scenario(graph, cost=cost, layer_grad_bytes=grads,
                    workers=workers), title


def cluster_whatif_report(module, cfg, cost, *, workers: int,
                          straggler: str = "",
                          critical_path: bool = False,
                          timeline: bool = False) -> str:
    """Cluster-simulate the compiled step across ``workers`` replicas."""
    # validate the straggler spec before the (expensive) graph extraction
    if straggler:
        _parse_straggler(straggler, workers)
    from repro.core.optimize import DDP
    scenario, title = build_scenario(module, cfg, cost, workers=workers,
                                     straggler=straggler)
    pred = scenario.predict(DDP())
    out = format_cluster_report(pred.cluster, title=title)
    if critical_path:
        out += "\n" + pred.critical_path.format()
    if timeline:
        from repro.obs import format_timeline_report
        out += "\n" + format_timeline_report(pred.timelines)
    return out


def export_prediction(pred, tf, cg, dest: str) -> str:
    """Write a prediction's timeline as Chrome trace JSON (Perfetto).

    Cluster routes write one re-importable file per worker into ``dest``
    (a directory); single-graph routes write one file at ``dest``.
    """
    from repro import traceio
    acts, grads = pred.byte_maps or (None, None)
    if cg is not None:
        # collectives (coll_gid) and point-to-point hops (p2p provenance)
        # both round-trip through --trace-dir re-import, pipeline
        # placements included; byte maps size the memory counter tracks
        paths = traceio.export_cluster_traces(cg, pred.cluster, dest,
                                              activation_bytes=acts,
                                              layer_grad_bytes=grads)
        return (f"exported {len(paths)} per-worker Chrome traces to "
                f"{dest}/ (open in https://ui.perfetto.dev; re-import with "
                f"--trace-dir)")
    if dest.endswith(".json"):
        path = dest
    else:
        os.makedirs(dest, exist_ok=True)
        path = os.path.join(dest, "trace.json")
    traceio.export_graph_trace(tf.graph, pred.result, path,
                               activation_bytes=acts,
                               layer_grad_bytes=grads)
    return f"exported Chrome trace to {path} (open in https://ui.perfetto.dev)"


def whatif_stack_report(module, cfg, cost, spec: str, *, workers: int = 0,
                        straggler: str = "", export_trace: str = "",
                        critical_path: bool = False,
                        timeline: bool = False) -> str:
    """Evaluate a registry-parsed optimization stack on the compiled step.

    ``spec`` is the CLI form parsed against the optimization registry, e.g.
    ``amp,ddp:workers=16,zero`` — commas stack optimizations (applied left
    to right), colons attach ``param=value`` pairs; a ``workers=N`` pair
    sets the scenario's analytical worker count.  Combine with
    ``--cluster N`` to route the same stack through the global ClusterGraph
    and get the per-worker table, and ``--export-trace`` to dump the
    predicted timeline for Perfetto.
    """
    from repro.core.optimize import parse_stack
    import dataclasses as _dc
    opt, overrides = parse_stack(spec)     # fail fast on bad specs
    if workers and "workers" in overrides:
        raise SystemExit(
            f"--what-if sets workers={overrides['workers']} but --cluster "
            f"{workers} was also given; pick one (--cluster routes through "
            f"the global ClusterGraph, workers=N in the spec is the "
            f"analytical route)")
    scenario, title = build_scenario(module, cfg, cost,
                                     workers=workers or 1,
                                     straggler=straggler)
    if overrides:
        scenario = _dc.replace(scenario, **overrides)
    pred, tf, cg = scenario.evaluate(opt)
    lines = [f"== what-if {spec} =="]
    for o in (opt.opts if hasattr(opt, "opts") else (opt,)):
        lines.append(f"   {o.spec()}")
    lines.append(f"baseline  : {pred.baseline * 1e3:10.3f} ms")
    lines.append(f"predicted : {pred.predicted * 1e3:10.3f} ms "
                 f"({pred.speedup:.2f}x)")
    if pred.cluster is not None:
        lines.append(format_cluster_report(
            pred.cluster, title=title or f"cluster x{len(pred.cluster.workers)}"))
    if critical_path:
        lines.append(pred.critical_path.format())
    if timeline:
        from repro.obs import format_timeline_report
        lines.append(format_timeline_report(pred.timelines))
    if export_trace:
        lines.append(export_prediction(pred, tf, cg, export_trace))
    return "\n".join(lines)


def load_trace_scenario(trace_dir: str, straggler: str = ""):
    """Import a per-worker trace dir into a ready-to-diagnose Scenario.

    Prints the per-worker import summary (event counts, clock fits, start
    skews), derives gradient payloads for insertion-style what-ifs
    (ddp/zero on a trace without collectives: traced collective payload
    split over the traced backward layers), and layers an optional
    ``IDX:SLOWDOWN`` straggler spec on top of the traced speeds.  Shared
    by ``perf_report --trace-dir`` and ``repro.launch.diagnose``; returns
    ``(ImportedCluster, Scenario)``.
    """
    from repro import traceio
    from repro.core.optimize import Scenario
    imp = traceio.load_trace_dir(trace_dir)
    n = imp.num_workers
    print(f"== imported {n} worker trace(s) from {trace_dir} ==")
    for i, al in enumerate(imp.alignments):
        print(f"w{i}: {len(imp.traces[i].events)} events, clock "
              f"scale={al.scale:.6f} offset={al.offset*1e3:+.3f}ms "
              f"({al.anchors} anchors), start skew "
              f"{imp.start_skews[i]*1e3:.3f}ms")

    g0 = imp.graphs[0]
    layers = sorted({t.layer for t in g0.tasks()
                     if t.layer and t.phase == "bwd"})
    total = sum(t.comm_bytes for t in g0.tasks()
                if t.attrs.get("collective"))
    grads = {l: total / len(layers) for l in layers} \
        if layers and total else None

    workers = None
    if straggler:
        idx, slow = _parse_straggler(straggler, n)
        workers = [WorkerSpec(compute_scale=slow if i == idx else 1.0)
                   for i in range(n)]
    return imp, Scenario(traces=imp, layer_grad_bytes=grads,
                         workers=workers if workers is not None else 1)


def trace_report(args) -> None:
    """``--trace-dir`` route: import real per-worker profiler traces
    (Chrome trace-event JSON / native JSONL — see :mod:`repro.traceio`),
    run an optimization stack from the registry on the imported cluster,
    and optionally export the prediction back to Chrome format.

        PYTHONPATH=src python -m repro.launch.perf_report \\
            --trace-dir traces/ --what-if 'amp,bandwidth:factor=2' \\
            --export-trace predicted/
    """
    imp, scenario = load_trace_scenario(args.trace_dir, args.straggler)
    n = imp.num_workers
    spec = args.what_if or "noop"
    pred, tf, cg = scenario.evaluate(spec)
    if args.what_if:
        print(f"== what-if {spec} on imported traces ==")
        print(f"baseline  : {pred.baseline * 1e3:10.3f} ms")
        print(f"predicted : {pred.predicted * 1e3:10.3f} ms "
              f"({pred.speedup:.2f}x)")
    print(format_cluster_report(pred.cluster,
                                title=f"imported cluster x{n}"))
    if args.critical_path:
        print(pred.critical_path.format())
    if args.timeline:
        from repro.obs import format_timeline_report
        print(format_timeline_report(pred.timelines))
    if args.export_trace:
        print(export_prediction(pred, tf, cg, args.export_trace))


def serving_report(args) -> None:
    """``--serving`` route: open-loop request simulation on ``--arch``.

    Builds a seeded Poisson workload, prices it with the arch's registered
    :func:`repro.configs.serving_cost`, and prints the latency/goodput
    table for baseline + ``--what-if`` stack — no compilation, no serving.

        PYTHONPATH=src python -m repro.launch.perf_report --serving \\
            --arch tinyllama-1.1b --rate 50 --duration 5 \\
            --what-if 'continuous_batching,tp:degree=8'
    """
    from repro.configs import normalize_arch, serving_cost
    from repro.serving import (ServingPolicy, ServingScenario,
                               format_serving_table, poisson_workload)
    if not args.arch:
        raise SystemExit("--serving needs --arch")
    arch = normalize_arch(args.arch)
    wl = poisson_workload(args.rate, args.duration, seed=0)
    scn = ServingScenario(workload=wl, policy=ServingPolicy(mode="static"),
                          serving_cost=serving_cost(arch))
    preds = [scn.predict("noop")]
    if args.what_if:
        preds.append(scn.predict(args.what_if))
    print(f"== serving {arch}: {len(wl)} requests, "
          f"{wl.offered_rate():.1f} req/s offered ==")
    print(format_serving_table(preds))
    if args.critical_path:
        print(preds[-1].critical_path.format())
    if args.timeline:
        from repro.obs import format_timeline_report
        print(format_timeline_report(preds[-1].timelines))
    if args.export_trace:
        from repro.traceio import export_graph_trace
        p = preds[-1]
        print(export_graph_trace(p.graph, p.result, args.export_trace))


def goodput_section(scenario, args) -> str:
    """``--goodput``: wrap a built training scenario in a
    :class:`repro.faults.FaultScenario` and report useful steps/hour,
    availability and lost work for the baseline + ``--what-if`` stack.
    Composes with ``--trace-dir`` (imported cluster) and with the
    compiled-arch route (add ``--cluster N`` for a data-parallel fleet).
    """
    from repro.faults import FaultScenario, format_goodput_table

    fscn = FaultScenario(
        graph=scenario.graph, cost=scenario.cost,
        layer_grad_bytes=scenario.layer_grad_bytes,
        activation_bytes=scenario.activation_bytes,
        workers=scenario.workers, traces=scenario.traces,
        collective_mode=scenario.collective_mode,
        mtbf_s=args.mtbf_hours * 3600.0, horizon_s=args.goodput_horizon,
        ckpt_interval_steps=args.ckpt_interval)
    base = "noop" if fscn.traces is not None or fscn.num_workers == 1 \
        else "ddp"
    preds = [fscn.predict(base)]
    if args.what_if:
        preds.append(fscn.predict(args.what_if))
    lines = [f"== goodput: {fscn.num_workers} worker(s), per-worker MTBF "
             f"{args.mtbf_hours:.1f}h, horizon "
             f"{args.goodput_horizon / 3600.0:.1f}h, ckpt every "
             f"{args.ckpt_interval} steps ==",
             f"recovery: {fscn.recovery.describe()}",
             format_goodput_table(preds)]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--tag", default="modeled_flash")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--cluster", type=int, default=0,
                    help="also cluster-simulate N data-parallel workers")
    ap.add_argument("--straggler", default="",
                    help="IDX:SLOWDOWN, e.g. 0:2.0 (with --cluster)")
    ap.add_argument("--what-if", default="", dest="what_if",
                    help="registry-parsed optimization stack, e.g. "
                         "'amp,ddp:workers=16,zero' or "
                         "'pipeline:stages=4,microbatches=16,schedule=1f1b'"
                         " (see repro.core.optimize; combine with --cluster"
                         " for per-worker breakdown; pipeline placements"
                         " always report per-stage workers)")
    ap.add_argument("--trace-dir", default="", dest="trace_dir",
                    help="import per-worker profiler traces (Chrome JSON / "
                         "native JSONL, one file per worker) instead of "
                         "compiling --arch; runs --what-if on the imported "
                         "cluster (see repro.traceio)")
    ap.add_argument("--export-trace", default="", dest="export_trace",
                    help="write the predicted timeline as Chrome trace JSON "
                         "(per-worker files on cluster routes) for Perfetto")
    ap.add_argument("--critical-path", action="store_true",
                    dest="critical_path",
                    help="print the predicted timeline's makespan-defining "
                         "chain with compute/comm/host/idle attribution "
                         "(repro.analysis; composes with --what-if, "
                         "--cluster, and --trace-dir)")
    ap.add_argument("--timeline", action="store_true",
                    help="print the predicted timeline's counter rollups "
                         "(per-worker utilization, peak live memory, "
                         "ready-queue depth, COMM bytes in flight — "
                         "repro.obs; composes with every route)")
    ap.add_argument("--telemetry", default="",
                    help="append the tool's own span telemetry (import, "
                         "build, retune, sweep, calibrate timings) as "
                         "JSONL to this path (repro.obs.spans; same as "
                         "REPRO_TELEMETRY=<path>)")
    ap.add_argument("--serving", action="store_true",
                    help="serving route: simulate an open-loop request "
                         "workload on --arch instead of compiling a "
                         "training cell; --what-if takes serving stacks "
                         "(continuous_batching, chunked_prefill, tp, ...) "
                         "— see repro.launch.serve_sim for the full knob "
                         "surface")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="(--serving) Poisson arrival rate, req/s")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="(--serving) arrival window, seconds")
    ap.add_argument("--goodput", action="store_true",
                    help="goodput route: wrap the built scenario in a "
                         "fault-injection simulation (repro.faults) and "
                         "report useful steps/hour under the --mtbf-hours "
                         "failure process; composes with --trace-dir and "
                         "--cluster, --what-if takes fault-policy stacks "
                         "(ckpt_interval, elastic, hot_spare, "
                         "straggler_mitigation) — see repro.launch.goodput "
                         "for the full knob surface")
    ap.add_argument("--mtbf-hours", type=float, default=6.0,
                    help="(--goodput) per-worker MTBF, hours")
    ap.add_argument("--goodput-horizon", type=float, default=86400.0,
                    help="(--goodput) simulated wall-clock, seconds")
    ap.add_argument("--ckpt-interval", type=int, default=100,
                    help="(--goodput) baseline checkpoint interval, steps")
    args = ap.parse_args()

    if args.telemetry:
        from repro import obs
        obs.configure(args.telemetry)
    if args.serving:
        serving_report(args)
        return
    if args.trace_dir:
        if args.goodput:
            _, scenario = load_trace_scenario(args.trace_dir,
                                              args.straggler)
            print(goodput_section(scenario, args))
            return
        trace_report(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (unless --trace-dir)")

    cfg = registry.get_config(args.arch)
    for kv in args.set:
        k, v = kv.split("=", 1)
        cfg = cfg.with_(**{k: parse_value(v)})
    shape = registry.SHAPES[args.shape]
    multi = args.mesh == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    cost = CostModel(topo=mesh_topology(multi))
    from repro import compat
    with compat.set_mesh(mesh):
        cell = build_cell(cfg, shape, mesh)
        compiled = cell.lower().compile()
    module = parse_hlo_module(compiled.as_text())
    if args.goodput:
        scenario, _ = build_scenario(module, cfg, cost,
                                     workers=args.cluster or 1,
                                     straggler=args.straggler)
        print(goodput_section(scenario, args))
        return
    tot = aggregate_with_attention_split(module, cost)

    fb = flash_traffic(cfg, shape, chips)
    agg = {"flops": tot["flops"],
           "bytes": tot["bytes"] - tot["attn_bytes"] + fb,
           "collective_bytes": tot["collective_bytes"],
           "collective_s": tot["collective_s"]}
    base_agg = {"flops": tot["flops"], "bytes": tot["bytes"],
                "collective_bytes": tot["collective_bytes"],
                "collective_s": tot["collective_s"]}
    kw = dict(chips=chips, kind=shape.kind,
              n_active_params=active_params(cfg), seq_len=shape.seq_len,
              global_batch=shape.global_batch)
    base = roofline_report(base_agg, **kw)
    modeled = roofline_report(agg, **kw)
    print("compiled    :", format_row(args.arch, args.shape, args.mesh, base))
    print("with flash  :", format_row(args.arch, args.shape, args.mesh,
                                      modeled))
    if args.what_if:
        print(whatif_stack_report(module, cfg, cost, args.what_if,
                                  workers=args.cluster,
                                  straggler=args.straggler,
                                  export_trace=args.export_trace,
                                  critical_path=args.critical_path,
                                  timeline=args.timeline))
    elif args.cluster:
        if args.export_trace:
            # one evaluation feeds both the report and the export
            scenario, title = build_scenario(module, cfg, cost,
                                             workers=args.cluster,
                                             straggler=args.straggler)
            pred, tf, cg = scenario.evaluate("ddp")
            print(format_cluster_report(pred.cluster, title=title))
            if args.critical_path:
                print(pred.critical_path.format())
            if args.timeline:
                from repro.obs import format_timeline_report
                print(format_timeline_report(pred.timelines))
            print(export_prediction(pred, tf, cg, args.export_trace))
        else:
            print(cluster_whatif_report(module, cfg, cost,
                                        workers=args.cluster,
                                        straggler=args.straggler,
                                        critical_path=args.critical_path,
                                        timeline=args.timeline))
    elif args.export_trace or args.critical_path or args.timeline:
        scenario, _ = build_scenario(module, cfg, cost)
        pred, tf, cg = scenario.evaluate("noop")
        if args.critical_path:
            print(pred.critical_path.format())
        if args.timeline:
            from repro.obs import format_timeline_report
            print(format_timeline_report(pred.timelines))
        if args.export_trace:
            print(export_prediction(pred, tf, cg, args.export_trace))
    print(f"attention-loop bytes replaced: {tot['attn_bytes']/1e9:.1f} GB "
          f"-> flash kernel {fb/1e9:.2f} GB per device")
    os.makedirs(args.out, exist_ok=True)
    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
           "status": "ok", "what_if": args.what_if or None,
           "modeled": "flash_attention_substitution",
           "attn_bytes_removed": tot["attn_bytes"],
           "flash_bytes_added": fb,
           "roofline_compiled": base, "roofline": modeled}
    with open(os.path.join(
            args.out,
            f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"),
            "w") as f:
        json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
