"""Logical-axis sharding rules -> mesh PartitionSpecs.

Every parameter and activation in the model code is annotated with *logical*
axis names; this module maps them onto whatever physical mesh is active:

  logical axis   single-pod (data, model)   multi-pod (pod, data, model)
  ------------   -------------------------  -----------------------------
  "batch"        ("data",)                  ("pod", "data")
  "fsdp"         ("data",)                  ("pod", "data")   [param shard]
  "model"        ("model",)                 ("model",)        [TP]
  "expert"       ("model",)                 ("model",)        [EP]
  "tokens"       ("data", "model")          ("pod", "data", "model")
  "seq"          None (or "model" for SP)   None
  None           replicated                 replicated

The physical interpretation is resolved *at trace time* from the active mesh
(``jax.sharding.get_abstract_mesh``), so the same model code lowers correctly
on a laptop (no mesh: every rule degrades to no-op), the 256-chip pod, and the
512-chip multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

LogicalAxis = Optional[str]


def current_mesh():
    """The active (abstract) mesh, or None outside any mesh context."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names or m.empty:
            return None
        return m
    except Exception:
        return None


def mesh_axis_sizes() -> Dict[str, int]:
    m = current_mesh()
    if m is None:
        return {}
    return dict(zip(m.axis_names, m.axis_sizes))


def data_axes() -> Tuple[str, ...]:
    """All pure-data-parallel axes present on the active mesh."""
    sizes = mesh_axis_sizes()
    return tuple(a for a in ("pod", "data") if a in sizes)


def model_axis() -> Optional[str]:
    return "model" if "model" in mesh_axis_sizes() else None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved mapping from logical to physical axes.

    ``fsdp`` toggles parameter sharding over the data axes (ZeRO-3 style,
    all-gather at use); turning it off replicates parameters across data —
    a §Perf hillclimb knob.
    """

    fsdp: bool = True
    sequence_parallel: bool = False
    # §Perf iteration 1 (EXPERIMENTS.md): "baseline" shards weight contraction
    # dims over the data axes (GSPMD then all-reduces *activations* over
    # data); "v2" moves FSDP sharding to weight *output* dims so the data-axis
    # communication becomes weight all-gathers (params << activations).
    layout: str = "v2"

    def physical(self, logical: LogicalAxis, *, dim_size: Optional[int] = None
                 ) -> Union[None, str, Tuple[str, ...]]:
        sizes = mesh_axis_sizes()
        if not sizes or logical is None:
            return None
        v2 = self.layout == "v2"
        dp = self.layout == "dp"

        def fits(axes: Tuple[str, ...]) -> bool:
            if dim_size is None:
                return True
            n = 1
            for a in axes:
                n *= sizes.get(a, 1)
            return dim_size % n == 0 and n > 1

        model_ax = () if dp else (("model",) if "model" in sizes else ())
        batch_ax = data_axes() + ((("model",) if "model" in sizes else ())
                                  if dp else ())
        store_ax = batch_ax        # FSDP storage axes

        if logical == "batch":
            ax = batch_ax
            if fits(ax):
                return ax
            ax = data_axes()
            return ax if fits(ax) else None
        if logical == "fsdp":           # weight dim that is contracted in fwd
            if not self.fsdp or v2 or dp:
                return None
            ax = data_axes()
            return ax if fits(ax) else None
        if logical == "out_fsdp":       # weight output dim (safe FSDP shard)
            if not self.fsdp:
                return None
            ax = store_ax
            if fits(ax):
                return ax
            ax = data_axes()
            return ax if fits(ax) else None
        if logical in ("ff_mega", "vocab_mega"):
            # dp: pure FSDP storage over every axis.  v2: model only — the 2D
            # (model x data) variant was refuted in §Perf iter 1/deepseek
            # iter 2: any weight dim is contracted in fwd or bwd, so data-axis
            # sharding here turns into 256-chip activation all-reduces.
            if dp and self.fsdp:
                ax = store_ax
                if fits(ax):
                    return ax
            return model_ax if model_ax and fits(model_ax) else None
        if logical in ("model", "expert", "heads", "vocab", "ff", "kvseq"):
            return model_ax if model_ax and fits(model_ax) else None
        if logical == "tokens":
            ax = data_axes() + (("model",) if "model" in sizes else ())
            if fits(ax):
                return ax
            ax = data_axes()
            return ax if fits(ax) else None
        if logical == "seq":
            if self.sequence_parallel and "model" in sizes and fits(("model",)):
                return ("model",)
            return None
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical: LogicalAxis,
             dim_sizes: Optional[Sequence[Optional[int]]] = None) -> P:
        dims = dim_sizes or [None] * len(logical)
        phys = []
        used: set = set()
        for lg, ds in zip(logical, dims):
            p = self.physical(lg, dim_size=ds)
            if p is None:
                phys.append(None)
                continue
            axes = (p,) if isinstance(p, str) else tuple(p)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            if not axes:
                phys.append(None)
            elif len(axes) == 1:
                phys.append(axes[0])
            else:
                phys.append(axes)
        return P(*phys)


DEFAULT_RULES = ShardingRules()

# Active layout for model-internal constraint calls (shard / use_weight).
# Step factories set this from ModelConfig.layout at trace time so the same
# model code lowers under any layout without threading rules everywhere.
import contextvars as _cv

_ACTIVE_LAYOUT = _cv.ContextVar("repro_layout", default="v2")


def set_active_layout(layout: str) -> None:
    _ACTIVE_LAYOUT.set(layout)


def active_rules() -> ShardingRules:
    return ShardingRules(layout=_ACTIVE_LAYOUT.get())


def logical_spec(*logical: LogicalAxis, rules: ShardingRules = DEFAULT_RULES,
                 dim_sizes: Optional[Sequence[Optional[int]]] = None) -> P:
    return rules.spec(*logical, dim_sizes=dim_sizes)


def shard(x, *logical: LogicalAxis, rules: Optional[ShardingRules] = None):
    """``with_sharding_constraint`` by logical axes; no-op without a mesh."""
    m = current_mesh()
    if m is None:
        return x
    rules = rules or active_rules()
    dim_sizes = list(x.shape) if hasattr(x, "shape") else None
    spec = rules.spec(*logical, dim_sizes=dim_sizes)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# Weight-gather FSDP (§Perf iteration 2): storage shards weights over the
# data axes; at USE they are constrained to model-axis-only sharding, so
# GSPMD emits a (small) weight all-gather over data instead of partial-sum
# all-reduces of (large) activations.  Every weight dim is contracted in
# either fwd or bwd, so no storage layout avoids those ARs — gathering the
# weight is the only move that does.
def use_weight(w, *logical: LogicalAxis):
    """Constrain a stored (FSDP-sharded) weight to its compute layout."""
    m = current_mesh()
    if m is None:
        return w
    layout = _ACTIVE_LAYOUT.get()
    use_rules = ShardingRules(
        fsdp=False, layout="dp" if layout == "dp" else "baseline")
    dim_sizes = list(w.shape) if hasattr(w, "shape") else None
    spec = use_rules.spec(*logical, dim_sizes=dim_sizes)
    try:
        return jax.lax.with_sharding_constraint(w, spec)
    except Exception:
        return w
