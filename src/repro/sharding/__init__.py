from .rules import (ShardingRules, DEFAULT_RULES, logical_spec, shard,
                    use_weight, set_active_layout, active_rules,
                    data_axes, model_axis, mesh_axis_sizes,
                    current_mesh)

__all__ = ["ShardingRules", "DEFAULT_RULES", "logical_spec", "shard", "use_weight", "set_active_layout", "active_rules",
           "data_axes", "model_axis", "mesh_axis_sizes", "current_mesh"]
