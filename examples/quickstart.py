"""Quickstart: train a tiny model, trace it, ask Daydream what-if questions.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import trace_compiled, whatif
from repro.data import make_batch
from repro.models import build_model, make_train_step
from repro.optim import AdamW

# ----------------------------------------------------------- 1. a model
cfg = get_smoke_config("tinyllama-1.1b").with_(scan_layers=False,
                                               remat="none")
opt = AdamW(lr=1e-3)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
batch = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, seq_len=64, batch=4, step=0).items()}

# ----------------------------------------------------------- 2. a few steps
step = jax.jit(make_train_step(cfg, opt))
for i in range(5):
    state, metrics = step(state, batch)
    print(f"step {i}: loss={float(metrics['loss']):.4f}")

# ------------------------------------------- 3. Daydream: trace + simulate
bundle = trace_compiled(make_train_step(cfg, opt), state, batch)
base = bundle.simulate()
print(f"\nbaseline simulated step: {base.makespan*1e3:.3f} ms "
      f"({len(bundle.graph)} tasks)")
print("breakdown:", {k: f"{v*1e3:.2f}ms" for k, v in base.breakdown.items()})

# ------------------------------------------------- 4. what-if questions
amp = whatif.what_if_amp(bundle.graph).simulate()
print(f"What if mixed precision?      {base.makespan/amp.makespan:.2f}x")

fused = whatif.what_if_fused_optimizer(bundle.graph,
                                       bundle.cost).simulate()
print(f"What if a fused optimizer?    {base.makespan/fused.makespan:.2f}x")

grads = {f"layer{i}": 5e6 for i in range(cfg.n_layers)}
dist = whatif.what_if_distributed(bundle.graph, grads, num_workers=16)
dm = dist.simulate()
print(f"What about 16-way data parallel?  step becomes "
      f"{dm.makespan/base.makespan:.2f}x the single-worker step")

bw2 = whatif.what_if_bandwidth(dist.graph, 2.0).simulate()
print(f"...and with 2x network bandwidth? {dm.makespan/bw2.makespan:.2f}x "
      f"faster than that")
