"""Quickstart: train a tiny model, trace it, ask Daydream what-if questions.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import Scenario, trace_compiled, get_optimization
from repro.data import make_batch
from repro.models import build_model, make_train_step
from repro.optim import AdamW

# ----------------------------------------------------------- 1. a model
cfg = get_smoke_config("tinyllama-1.1b").with_(scan_layers=False,
                                               remat="none")
opt = AdamW(lr=1e-3)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
batch = {k: jnp.asarray(v) for k, v in
         make_batch(cfg, seq_len=64, batch=4, step=0).items()}

# ----------------------------------------------------------- 2. a few steps
step = jax.jit(make_train_step(cfg, opt))
for i in range(5):
    state, metrics = step(state, batch)
    print(f"step {i}: loss={float(metrics['loss']):.4f}")

# ------------------------------------------- 3. Daydream: trace + simulate
bundle = trace_compiled(make_train_step(cfg, opt), state, batch)
base = bundle.simulate()
print(f"\nbaseline simulated step: {base.makespan*1e3:.3f} ms "
      f"({len(bundle.graph)} tasks)")
print("breakdown:", {k: f"{v*1e3:.2f}ms" for k, v in base.breakdown.items()})

# ------------------------------------------------- 4. what-if questions
# One Scenario carries the graph, cost model, gradient bytes, and worker
# count; registered optimizations are named, typed, and stack with `|`.
grads = {f"layer{i}": 5e6 for i in range(cfg.n_layers)}
scenario = Scenario(bundle.graph, cost=bundle.cost,
                    layer_grad_bytes=grads, workers=16)

amp = scenario.predict("amp")
print(f"What if mixed precision?      {amp.speedup:.2f}x")

fused = scenario.predict("fused_optimizer")
print(f"What if a fused optimizer?    {fused.speedup:.2f}x")

ddp = get_optimization("ddp")()
dm = scenario.predict(ddp)
print(f"What about 16-way data parallel?  step becomes "
      f"{dm.predicted/dm.baseline:.2f}x the single-worker step")

# stacks compose left-to-right: DDP's all-reduces, then 2x faster links
bw2 = scenario.predict(ddp | get_optimization("bandwidth")(factor=2.0))
print(f"...and with 2x network bandwidth? {dm.predicted/bw2.predicted:.2f}x "
      f"faster than that")

# a parameter sweep is one call — no manual re-chaining per point
for pred in scenario.sweep("ddp", {"bucket_bytes": [1e6, 25e6, 100e6]}):
    print(f"...bucket {pred.point['bucket_bytes']/1e6:5.1f} MB: "
          f"{pred.predicted*1e3:.3f} ms/step")
