"""Goodput under failures: which fault policy actually buys useful steps?

A 16-worker data-parallel job with a 6-hour per-worker MTBF loses a
surprising fraction of its wall-clock to the failure pipeline: detection,
restore, restart, and the work rolled back to the last checkpoint.  The
``repro.faults`` subsystem predicts *useful steps per hour* (goodput) for a
fault-policy stack before you deploy it, the same way the graph what-ifs
predict step time:

1. sweep the checkpoint interval and compare the simulated optimum with
   the Young/Daly closed form ``tau* = sqrt(2 * ckpt_write * job_MTBF)``;
2. compare recovery policies — halt-and-repair vs elastic continuation vs
   hot spares — on the same seeded failure timeline;
3. ask whether straggler mitigation pays: it caps the dilation from
   transient slow workers but charges a per-step overhead, so the answer
   depends on how bad the straggler process is.

    PYTHONPATH=src python examples/goodput_demo.py
"""

from repro.faults import (demo_scenario, format_goodput_table,
                          young_daly_interval)


def main() -> None:
    scn = demo_scenario(workers=16, layers=8, mtbf_s=6 * 3600.0,
                        horizon_s=86400.0, seed=1, ckpt_interval_steps=100)
    rec = scn.recovery
    print(f"16 workers, per-worker MTBF 6h (job MTBF "
          f"{scn.job_mtbf_s / 60:.0f} min), 24h horizon")
    print(f"recovery: {rec.describe()}\n")

    # ---- 1. checkpoint-interval sweep vs Young/Daly -------------------
    best, points, k_yd = scn.optimal_ckpt_interval("ddp")
    tau = young_daly_interval(rec.checkpoint_write_s, scn.job_mtbf_s)
    print(f"== checkpoint interval (Young/Daly optimum {tau:.0f}s "
          f"~= {k_yd} steps) ==")
    for p in points:
        k = p.policy.ckpt_interval_steps
        mark = "  <- best" if p is best else (
            "  <- Young/Daly" if k == k_yd else "")
        print(f"  every {k:>5d} steps: "
              f"{p.report.goodput_steps_per_hour:>9,.0f} useful steps/h "
              f"({p.report.goodput_fraction:.1%}){mark}")
    assert best.report.goodput_fraction <= 1.0

    # ---- 2. recovery policies on the same failure timeline ------------
    k = best.policy.ckpt_interval_steps
    stacks = [f"ddp,ckpt_interval:steps={k}",
              f"ddp,ckpt_interval:steps={k},elastic",
              f"ddp,ckpt_interval:steps={k},hot_spare:count=2"]
    preds = [scn.predict(s) for s in stacks]
    print("\n== recovery policy what-ifs ==")
    print(format_goodput_table(preds))
    halt, elastic, spare = preds
    assert elastic.goodput > halt.goodput, "elastic should beat halting"
    assert spare.goodput > halt.goodput, "hot spares should beat cold repair"

    # ---- 3. does straggler mitigation pay? -----------------------------
    print("\n== straggler mitigation (predict before enabling) ==")
    procs = [("light (0.5/h, 1.5x)", dict(straggler_rate_per_hour=0.5,
                                          straggler_slowdown=1.5,
                                          straggler_duration_s=120.0)),
             ("heavy (20/h, 3x)", dict(straggler_rate_per_hour=20.0,
                                       straggler_slowdown=3.0,
                                       straggler_duration_s=600.0))]
    for label, proc in procs:
        s = demo_scenario(workers=16, layers=8, mtbf_s=0.0,
                          horizon_s=86400.0, seed=3, **proc)
        off = s.predict("ddp").goodput
        on = s.predict("ddp,straggler_mitigation").goodput
        verdict = "pays" if on > off else "does NOT pay"
        print(f"  {label:>20}: off {off:>9,.0f} -> on {on:>9,.0f} "
              f"useful steps/h  ({verdict})")


if __name__ == "__main__":
    main()
