"""Diagnosis end-to-end: capture -> fidelity diff -> ranked what-ifs.

The PR-5 workflow (repro.analysis): a captured per-worker trace set is
not just input for one what-if number — it is something to *explain*:

  1. generate a synthetic "profiled" capture (4 workers, one straggler,
     skewed clocks — what real profilers hand you),
  2. import it and diff the simulator's reproduction against the capture
     task-by-task (paper §6's validation methodology as a tool): per-kind
     error rollups say how much to trust the predictions,
  3. extract the critical path of the step — the chain of tasks that
     *is* the makespan — attributed into compute / comm / host / idle per
     worker,
  4. rank every registered optimization by its Amdahl-style speedup upper
     bound (computed through the real simulator) next to its realized
     depth-1 speedup, and
  5. evaluate the top-ranked concrete stack and compare critical paths
     before and after.

    PYTHONPATH=src python examples/diagnose.py [--workers 4] [--out DIR]

CLI equivalent: ``python -m repro.launch.diagnose --trace-dir DIR``.
"""

import argparse
import os
import tempfile

from repro import traceio
from repro.analysis import (diff_prediction, format_opportunity_table,
                            rank_opportunities)
from repro.core import Scenario


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--out", default="",
                    help="where to put the trace dir (default: tempdir)")
    args = ap.parse_args()
    root = args.out or tempfile.mkdtemp(prefix="diagnose_")
    n = args.workers

    # 1. the capture: worker 1 is a 1.5x straggler, clocks are skewed
    trace_dir = os.path.join(root, "captured")
    scales = [1.0, 1.5] + [1.0] * (n - 2)
    traceio.write_synthetic_trace_dir(
        trace_dir, n, layers=args.layers, compute_scales=scales,
        clock_offsets=[0.007 * w for w in range(n)],
        clock_drifts=[1.0 + 1e-4 * w for w in range(n)])
    print(f"wrote {n} per-worker JSONL traces to {trace_dir}/\n")

    # 2. fidelity: how well does the simulator reproduce the capture?
    imp = traceio.load_trace_dir(trace_dir)
    grads = {f"l{i}": 30e6 for i in range(args.layers)}
    scenario = Scenario(traces=imp, layer_grad_bytes=grads)
    pred, tf, cg = scenario.evaluate("noop")
    diff = diff_prediction(pred, tf, cg, imp)
    print(diff.format(top=5))
    print()

    # 3. why is the step this slow?  The straggler's compute chain should
    # dominate the path; collectives show up as comm on every worker.
    print(pred.critical_path.format(top=6))
    print()

    # 4. what is worth trying first?  Bounds prove what *cannot* help.
    opps = rank_opportunities(scenario, realize=True)
    print(format_opportunity_table(opps))
    print()

    # 5. act on the ranking: best bounded candidate with real headroom
    best = next(o for o in opps
                if not o.unbounded and not o.skipped and o.realized)
    spec = best.optimization.spec()
    wpred = scenario.predict(best.optimization)
    print(f"applying top-ranked candidate {spec}: "
          f"{wpred.baseline * 1e3:.3f} ms -> {wpred.predicted * 1e3:.3f} ms "
          f"({wpred.speedup:.2f}x; bound said <= {best.bound:.2f}x)")
    print(wpred.critical_path.format(top=6))


if __name__ == "__main__":
    main()
