"""Serving what-ifs end to end: workload -> graph -> latency/goodput table.

The ISSUE-7 workflow (repro.serving): Daydream's predict-before-you-build
recipe pointed at inference serving —

  1. generate a seeded open-loop Poisson workload (the regime in which
     batching policies actually differ: requests arrive on their own
     clock whether or not the engine keeps up),
  2. lower the baseline policy (static slots — the seed
     ``repro/serve.ServeEngine`` semantics) into a dependency graph and
     verify the static-batch drain-time invariant against the analytic
     closed form,
  3. predict what continuous batching, chunked prefill, and TP=2 would
     each do to p50/p99 TTFT and goodput — through the same registry /
     ``Stack`` machinery as the training what-ifs, nothing is served,
  4. check the headroom bound covers the realized speedup, and
  5. diagnose the best stack's critical path on the serving graph.

    PYTHONPATH=src python examples/serving_whatif.py
"""

from repro.analysis.opportunity import opportunity_bound
from repro.serving import (ContinuousBatching, ServingCostModel,
                           ServingPolicy, ServingScenario,
                           explicit_workload, format_serving_table,
                           poisson_workload)


def main() -> None:
    cost = ServingCostModel()

    # -- 2. drain-time invariant on a pinned single batch ---------------
    slots, prompt, budget = 4, 100, 16
    one_batch = explicit_workload([(0.0, prompt, budget)] * slots)
    pinned = ServingScenario(workload=one_batch, serving_cost=cost,
                             policy=ServingPolicy(mode="static",
                                                  slots=slots))
    kv = slots * (prompt + budget)
    analytic = slots * cost.prefill_time(prompt) \
        + budget * cost.decode_step_time(slots, kv)
    got = pinned.baseline().makespan
    assert abs(got - analytic) <= 1e-12 * analytic
    print(f"static drain invariant: simulated {got * 1e3:.4f} ms == "
          f"analytic prefill + budget*step ({analytic * 1e3:.4f} ms)\n")

    # -- 1 & 3. saturating open-loop traffic, three what-ifs ------------
    wl = poisson_workload(rate=200, duration=0.5, seed=1,
                          prompt_mean=64, prompt_sigma=0.5,
                          output_mean=16, output_sigma=0.5)
    scn = ServingScenario(workload=wl, serving_cost=cost,
                          policy=ServingPolicy(mode="static", slots=8))
    print(f"workload: {len(wl)} requests, "
          f"{wl.offered_rate():.0f} req/s offered, "
          f"{wl.total_output_tokens} output tokens\n")
    preds = [scn.predict("noop"),
             scn.predict("continuous_batching"),
             scn.predict("continuous_batching,chunked_prefill:chunk=64"),
             scn.predict("continuous_batching,tp:degree=2")]
    print(format_serving_table(preds))

    # -- 4. headroom bound covers the realized speedup ------------------
    bound = opportunity_bound(scn, ContinuousBatching())
    best = max(preds, key=lambda p: p.speedup)
    assert bound >= best.speedup
    print(f"\nheadroom bound (arrival floor): <= {bound:.2f}x; best "
          f"realized {best.optimization.spec()} at {best.speedup:.2f}x")

    # -- 5. critical-path diagnosis works unchanged ---------------------
    cp = best.critical_path
    bd = cp.breakdown()
    top = max(bd, key=bd.get)
    print(f"critical path: {len(cp.segments)} segments, dominated by "
          f"{top} ({bd[top] / cp.makespan:.0%} of makespan)")


if __name__ == "__main__":
    main()
