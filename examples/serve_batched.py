"""Batched serving example: prefill + greedy decode over a shared KV cache.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ServeEngine, Request


def main() -> None:
    cfg = get_smoke_config("llama3.2-1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_seq=96)

    rng = np.random.default_rng(7)
    requests = [Request(prompt=list(rng.integers(1, cfg.vocab, n)),
                        max_new_tokens=24)
                for n in (5, 9, 16, 3)]
    t0 = time.time()
    results = engine.generate(requests)
    dt = time.time() - t0
    tot = sum(len(r.tokens) for r in results)
    print(f"{len(requests)} requests, {tot} tokens in {dt:.2f}s "
          f"({tot/dt:.1f} tok/s)")
    for i, r in enumerate(results):
        print(f"req{i} (prompt {len(requests[i].prompt)} toks) -> "
              f"{[int(t) for t in r.tokens[:10]]}...")


if __name__ == "__main__":
    main()
