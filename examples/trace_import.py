"""Trace import end-to-end: profiled per-worker traces -> what-if answers.

The PR-3 workflow (dPRO-style, repro.traceio): instead of replicating one
analytical profile, start from what a *profiler on each worker* would
capture — N independently-clocked trace files — and

  1. generate such a trace set synthetically (4 workers, one a 1.6x
     straggler, each with its own clock offset/drift),
  2. import it (clock alignment + per-worker graph reconstruction +
     cross-worker collective matching),
  3. run a what-if stack from the PR-2 optimization registry on the
     imported asymmetric cluster,
  4. export the predicted timeline back to Chrome trace JSON for Perfetto,
     and re-import it to show the round trip holds.

    PYTHONPATH=src python examples/trace_import.py [--workers 4] [--out DIR]
"""

import argparse
import os
import tempfile

from repro.core import ClusterGraph, Scenario, WorkerSpec
from repro import traceio
from repro.launch.perf_report import format_cluster_report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--out", default="",
                    help="where to put the trace dirs (default: tempdir)")
    args = ap.parse_args()
    root = args.out or tempfile.mkdtemp(prefix="trace_import_")
    n = args.workers

    # 1. a synthetic "profiled" trace set: worker 0 is a 1.6x straggler and
    # every worker's clock is skewed — what real captures look like.
    trace_dir = os.path.join(root, "captured")
    scales = [1.6] + [1.0] * (n - 1)
    offsets = [((-1) ** w) * 0.013 * w for w in range(n)]
    drifts = [1.0 + 2e-4 * w for w in range(n)]
    traceio.write_synthetic_trace_dir(
        trace_dir, n, layers=args.layers, compute_scales=scales,
        clock_offsets=offsets, clock_drifts=drifts)
    print(f"wrote {n} per-worker JSONL traces to {trace_dir}/")

    # 2. import: alignment undoes the clocks, graphs come from stream order
    # + explicit deps, collectives are matched across workers.
    imp = traceio.load_trace_dir(trace_dir)
    for i, al in enumerate(imp.alignments):
        print(f"  w{i}: clock scale={al.scale:.6f} "
              f"offset={al.offset * 1e3:+8.3f}ms ({al.anchors} anchors)")

    scenario = Scenario(traces=imp)
    base = scenario.predict("noop")
    print(format_cluster_report(base.cluster, title="imported baseline"))

    # 3. what-ifs from the PR-2 registry run unchanged on the imported
    # cluster: single optimizations, stacks, and spec strings all work.
    for spec in ("amp", "bandwidth:factor=4", "amp,bandwidth:factor=4"):
        pred = scenario.predict(spec)
        print(f"what-if {spec:26s}: {pred.baseline * 1e3:8.3f} ms -> "
              f"{pred.predicted * 1e3:8.3f} ms ({pred.speedup:.2f}x)")

    # ...including what-ifs *about the cluster itself*: what if the
    # straggler were fixed?  Scale worker 0's traced durations down.
    fixed = [WorkerSpec(compute_scale=1.0 / scales[i] if i == 0 else 1.0)
             for i in range(n)]
    pred = Scenario(traces=imp, workers=fixed).predict("noop")
    print(f"what-if fix straggler        : {base.predicted * 1e3:8.3f} ms -> "
          f"{pred.predicted * 1e3:8.3f} ms "
          f"({base.predicted / pred.predicted:.2f}x)")

    # 4. export the best prediction for Perfetto and close the loop.
    pred, tf, cg = scenario.evaluate("amp,bandwidth:factor=4")
    pred_dir = os.path.join(root, "predicted")
    traceio.export_cluster_traces(cg, pred.cluster, pred_dir)
    re_imported = ClusterGraph.from_traces(pred_dir).simulate()
    print(f"exported prediction to {pred_dir}/ (open in "
          f"https://ui.perfetto.dev)")
    print(f"round trip: predicted {pred.predicted * 1e3:.3f} ms, "
          f"re-imported {re_imported.makespan * 1e3:.3f} ms")


if __name__ == "__main__":
    main()
