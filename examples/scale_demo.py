"""Interactive what-ifs at 10k-worker scale: folding + incremental replay.

A data-parallel cluster is mostly copies of the same worker.  Symmetry
folding (repro.core.fold) partitions workers into equivalence classes,
materializes ONE representative per class, and closes the collectives
algebraically over the class sizes — exact, not approximate: the folded
makespan is identical to the fully materialized build, it just simulates
hundreds of lanes instead of tens of thousands.  On top of that,
``simulate_incremental`` replays only the dirty downstream cone after a
``retune``, so a bandwidth sweep re-simulates a few percent of the graph
per point.

    PYTHONPATH=src python examples/scale_demo.py
"""

import time

from repro.core import ClusterGraph, WorkerSpec, fold_cluster, whatif
from repro.analysis import cluster_critical_path
from repro.core.graph import DependencyGraph
from repro.core.task import DEVICE_STREAM, HOST_THREAD, Task, TaskKind
from repro.parallel.plan import ParallelPlan, StageProfile


def step_graph(layers: int = 12) -> DependencyGraph:
    g = DependencyGraph()
    h = g.add_task(Task("host:dispatch", TaskKind.HOST, HOST_THREAD, 20e-6))
    for i in range(layers):
        t = g.add_task(Task(f"fwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM,
                            1e-3, layer=f"l{i}", phase="fwd"))
        if i == 0:
            g.add_edge(h, t)
    for i in reversed(range(layers)):
        g.add_task(Task(f"bwd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 2e-3,
                        layer=f"l{i}", phase="bwd"))
        g.add_task(Task(f"upd:l{i}", TaskKind.COMPUTE, DEVICE_STREAM, 1e-4,
                        layer=f"l{i}", phase="update"))
    return g


def main() -> None:
    # ---- 1. folding is exact: one straggler splits 64 workers in two ----
    grads = {f"l{i}": 40e6 for i in range(12)}
    ddp = whatif.what_if_distributed(step_graph(), grads,
                                     num_workers=64).graph
    specs = [WorkerSpec(compute_scale=2.0 if i == 0 else 1.0)
             for i in range(64)]
    fg = fold_cluster(ddp, specs, collective_mode="fused")
    folded = fg.simulate()
    materialized = ClusterGraph.build(ddp, specs,
                                      collective_mode="fused").simulate()
    assert folded.makespan == materialized.makespan
    print(f"64-worker DDP, one 2x straggler: {fg.num_classes} classes, "
          f"{len(fg.graph)} folded tasks "
          f"(makespan {folded.makespan * 1e3:.3f} ms == materialized, "
          f"exact)")
    for cls in fg.classes:
        print(f"  class rep w{cls.representative}: {len(cls.members)} "
              f"member(s)")

    # per-class critical-path attribution — worker-level answers without
    # expanding the classes
    cp = cluster_critical_path(fg)
    for rep, secs in sorted(cp.per_class(fg.classes).items(),
                            key=lambda kv: (kv[0] is None, kv[0])):
        who = f"w{rep}" if rep is not None else "sync"
        print(f"  on-path time {who}: {secs * 1e3:.3f} ms")

    # ---- 2. a 4096-worker hybrid PP x DP sweep, interactively ----------
    profs = tuple(StageProfile(index=s, layers=(f"l{s}",), fwd_s=2e-3,
                               bwd_s=4e-3, update_s=1e-3, act_bytes=16e6,
                               grad_bytes=64e6) for s in range(8))
    plan = ParallelPlan(profs, 8, "gpipe", dp=512)    # 8 stages x 512 = 4096
    t0 = time.perf_counter()
    fg = plan.fold_place()
    prev = fg.simulate()
    print(f"\nhybrid 8-stage x 512-way DP ({plan.num_workers} workers): "
          f"{fg.num_classes} classes, {len(fg.graph)} folded tasks, "
          f"first point {time.perf_counter() - t0:.2f}s")
    print("bandwidth sweep (retune + incremental cone replay, full "
          "fallback):")
    for bw in (0.25, 0.5, 1.0, 2.0, 4.0):
        t0 = time.perf_counter()
        fg.retune([WorkerSpec(bandwidth_scale=bw)] * plan.num_workers)
        res = fg.simulate_incremental(prev)
        route = "incremental"
        if res is None:
            res, route = fg.simulate(), "full"
        print(f"  {bw:5.2f}x links: {res.makespan * 1e3:9.3f} ms "
              f"({time.perf_counter() - t0:.3f}s, {route}, dirty "
              f"{len(fg.last_retune_dirty)}/{len(fg.graph)})")
        prev = res


if __name__ == "__main__":
    main()
