"""The paper's full workflow on one model: trace once, model ten optimizations.

Reproduces the Table-1 coverage claim through the *unified* what-if API
(repro.core.optimize): every optimization family the paper models is a
registered, typed, composable `Optimization`.  One `Scenario` carries the
traced graph, the cost model, and the per-layer byte maps, so each what-if
is a one-liner — `scenario.predict("amp")` — stacks compose with `|`, and
parameter grids run through `Scenario.sweep`, which reuses one ClusterGraph
build across sweep points instead of rebuilding per point.

    PYTHONPATH=src python examples/whatif_analysis.py [--arch tinyllama-1.1b]
"""

import argparse

from repro.core import Scenario, WorkerSpec, get_optimization
from repro.core.optimize import uniform_bandwidth_specs

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import traced_train, layer_grad_bytes  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()

    bundle = traced_train(args.arch)
    grads = layer_grad_bytes(args.arch)
    acts = {l: 2e6 for l in grads}

    # One scenario object replaces the per-function kwarg threading: graph,
    # cost model, byte maps, and the worker spec live in one place.
    scenario = Scenario(bundle.graph, cost=bundle.cost,
                        layer_grad_bytes=grads, activation_bytes=acts,
                        workers=16)
    base = scenario.baseline().makespan
    print(f"{args.arch}: baseline {base*1e3:.3f} ms, "
          f"{len(bundle.graph)} tasks, {len(grads)} mapped layers\n")

    # single-graph what-ifs: each entry is a registry spec string
    print(f"{'optimization':28s} {'predicted':>10s}")
    singles = [
        ("AMP (mixed precision)", "amp"),
        ("FusedAdam", "fused_optimizer"),
        ("Fused norm (ReconBN)", "fused_norm"),
        ("MetaFlow scale attn 0.7", "scale_layer:layer_pattern=attn:scale=0.7"),
        ("Gist (encode/decode)", "gist:layer_pattern=layer"),
        ("vDNN (offload)", "offload:layer_pattern=layer"),
    ]
    for name, spec in singles:
        print(f"{name:28s} {scenario.predict(spec).speedup:9.2f}x")

    # distributed what-ifs: DDP composes with each follow-on via `|` — the
    # stack applies left to right on one transform, no manual graph chaining
    ddp = get_optimization("ddp")()
    dbase = scenario.predict(ddp).predicted
    print(f"\n16-worker DP baseline: {dbase*1e3:.3f} ms")
    stacked = [
        ("DGC 1% compression", "dgc:compression=0.01"),
        ("BlueConnect 4x4", "blueconnect:axes=[('data',4),('model',4)]"),
        ("ZeRO opt-sharding", "zero"),
        ("Async collectives", "overlap"),
        ("2x bandwidth", "bandwidth:factor=2.0"),
        ("Straggler 1.5x", "straggler"),
    ]
    for name, spec in stacked:
        pred = scenario.predict(f"ddp,{spec}")
        print(f"{name:28s} {dbase / pred.predicted:9.2f}x")

    # scaling sweep (Fig. 8 style): one grid over the scenario's worker count
    print("\nscaling sweep (Fig. 8 style):")
    for pred in scenario.sweep("ddp", {"workers": [2, 4, 8, 16, 32, 64]}):
        m = pred.predicted
        print(f"  {pred.point['workers']:3d} workers: step {m*1e3:9.3f} ms "
              f"({m/base:.2f}x single)")

    # cluster bandwidth sweep: 6 points, ONE ClusterGraph build — each point
    # retunes the ring-leg durations in place (ClusterGraph.retune) and
    # re-simulates, with a per-worker breakdown available on every point
    cluster = Scenario(bundle.graph, cost=bundle.cost,
                       layer_grad_bytes=grads,
                       workers=[WorkerSpec() for _ in range(8)])
    scales = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0]
    grid = {"workers": uniform_bandwidth_specs(8, scales)}
    print("\ncluster bandwidth sweep (8 workers, one graph build):")
    for s, pred in zip(scales, cluster.sweep("ddp", grid)):
        print(f"  {s:5.2f}x links: step {pred.predicted*1e3:9.3f} ms, "
              f"straggler w{pred.cluster.straggler()}")

    # pipeline / hybrid parallelism through the same registry: the traced
    # profile is partitioned into balanced stages and placed on real
    # workers through the cluster simulator — p2p activation/gradient hops,
    # per-stage DP gradient rings — so "will 1F1B help my config?" is one
    # predict() away, composable with every other what-if
    pp = Scenario(bundle.graph, cost=bundle.cost, layer_grad_bytes=grads,
                  activation_bytes=acts)
    print("\npipeline / hybrid PPxDP (device-program makespan; host "
          "dispatch not modeled):")
    pipelines = [
        ("GPipe 4 stages x 16 mb", "pipeline:stages=4,microbatches=16"),
        ("1F1B  4 stages x 16 mb",
         "pipeline:stages=4,microbatches=16,schedule=1f1b"),
        ("hybrid 4 stages x 4-way DP",
         "pipeline:stages=4,microbatches=16,dp=4"),
        ("hybrid | AMP | DGC",
         "pipeline:stages=4,microbatches=16,dp=4,amp,dgc:compression=0.01"),
    ]
    for name, spec in pipelines:
        pred = pp.predict(spec)
        print(f"{name:28s} {pred.speedup:9.2f}x "
              f"({pred.predicted*1e3:.3f} ms on "
              f"{len(pred.cluster.workers)} workers)")

    # microbatch sweep: the stage partition is computed once and cached;
    # each point only rebuilds the O(S*M) schedule graph
    print("\nmicrobatch sweep (one partition, O(S*M) rebuilds per point):")
    for pred in pp.sweep("pipeline",
                         {"stages": [4], "microbatches": [4, 8, 16, 32]}):
        print(f"  M={pred.point['microbatches']:3d}: "
              f"{pred.predicted*1e3:9.3f} ms")


if __name__ == "__main__":
    main()
