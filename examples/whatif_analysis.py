"""The paper's full workflow on one model: trace once, model ten optimizations.

Reproduces the Table-1 coverage claim: every optimization family the paper
models, expressed in a few lines of graph-transformation primitives, plus the
Fig. 8-style distributed scaling sweep — all from ONE single-device profile.

    PYTHONPATH=src python examples/whatif_analysis.py [--arch tinyllama-1.1b]
"""

import argparse

from repro.core import whatif, simulate

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import traced_train, layer_grad_bytes  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()

    bundle = traced_train(args.arch)
    grads = layer_grad_bytes(args.arch)
    acts = {l: 2e6 for l in grads}
    g = bundle.graph
    base = bundle.simulate().makespan
    print(f"{args.arch}: baseline {base*1e3:.3f} ms, {len(g)} tasks, "
          f"{len(grads)} mapped layers\n")

    print(f"{'optimization':28s} {'predicted':>10s}")
    rows = [
        ("AMP (mixed precision)", whatif.what_if_amp(g)),
        ("FusedAdam", whatif.what_if_fused_optimizer(g, bundle.cost)),
        ("Fused norm (ReconBN)", whatif.what_if_fused_norm(g)),
        ("MetaFlow scale attn 0.7", whatif.what_if_scale_layer(g, "attn", 0.7)),
        ("Gist (encode/decode)", whatif.what_if_gist(g, "layer", acts)),
        ("vDNN (offload)", whatif.what_if_offload(g, "layer", acts)),
    ]
    for name, tf in rows:
        s = base / tf.simulate().makespan
        print(f"{name:28s} {s:9.2f}x")

    dist = whatif.what_if_distributed(g, grads, 16).graph
    dbase = simulate(dist).makespan
    print(f"\n16-worker DP baseline: {dbase*1e3:.3f} ms")
    rows = [
        ("DGC 1% compression", whatif.what_if_dgc(dist, compression=0.01)),
        ("BlueConnect 4x4", whatif.what_if_blueconnect(
            dist, [("data", 4), ("model", 4)])),
        ("ZeRO opt-sharding", whatif.what_if_zero(dist, 16)),
        ("Async collectives", whatif.what_if_overlap_collectives(dist)),
        ("2x bandwidth", whatif.what_if_bandwidth(dist, 2.0)),
        ("Straggler 1.5x", whatif.what_if_straggler(dist)),
    ]
    for name, tf in rows:
        s = dbase / tf.simulate().makespan
        print(f"{name:28s} {s:9.2f}x")

    print("\nscaling sweep (Fig. 8 style):")
    for w in (2, 4, 8, 16, 32, 64):
        m = whatif.what_if_distributed(g, grads, w).simulate().makespan
        print(f"  {w:3d} workers: step {m*1e3:9.3f} ms "
              f"({m/base:.2f}x single)")


if __name__ == "__main__":
    main()
