"""End-to-end driver: train a ~100M-parameter LM on the synthetic pipeline.

Full substrate path: model -> data -> AdamW(+schedule) -> checkpoints ->
fault-tolerant runner.  Defaults are CPU-sized; pass --steps 300 for the
full few-hundred-step run (the loss visibly converges toward the synthetic
stream's structure).

    PYTHONPATH=src python examples/train_e2e.py --steps 30
"""

import argparse

from repro.data import make_batch, Prefetcher
from repro.models import ModelConfig, count_params
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig

CFG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=3072, vocab=8192, remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"model: {count_params(cfg)/1e6:.1f}M params")
    opt = AdamW(lr=warmup_cosine(3e-4, args.steps // 10 + 1, args.steps))
    tc = TrainerConfig(steps=args.steps, log_every=5,
                       ckpt_every=max(10, args.steps // 3),
                       ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tc, optimizer=opt)

    def batches():
        step = 0
        while True:
            yield make_batch(cfg, seq_len=args.seq, batch=args.batch,
                             step=step)
            step += 1

    trainer.fit(Prefetcher(batches()))
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"median step {sorted(m['step_time_s'] for m in trainer.metrics_log)[len(losses)//2]*1e3:.0f} ms; "
          f"straggler flags: {trainer.straggler.flagged}")


if __name__ == "__main__":
    main()
